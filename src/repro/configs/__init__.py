"""Architecture config registry.  Importing this package registers all archs."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    get_config,
    get_smoke_config,
    list_archs,
)

# importing each module registers its arch
from repro.configs import (  # noqa: F401
    gemma2_9b,
    granite_3_2b,
    granite_moe_1b,
    hymba_1_5b,
    llava_next_34b,
    mamba2_1_3b,
    minitron_8b,
    qwen15_110b,
    qwen3_moe_30b,
    whisper_small,
)

ALL_ARCHS = list_archs()
