"""hymba-1.5b — hybrid, 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 — parallel attn+mamba heads.  [arXiv:2411.13676; hf]

Hymba runs attention and SSM heads *in parallel* within each layer and fuses
their outputs; attention is sliding-window in most layers (3 global), which is
what makes long_500k sub-quadratic.
"""
from repro.configs.base import ModelConfig, SSMConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        sliding_window=1024,
        local_global_alternate=False,  # hymba: local everywhere (3 global handled as local window here)
        ssm=SSMConfig(state_size=16, num_heads=25, head_dim=64, chunk_size=256),
        source="arXiv:2411.13676 (nvidia/Hymba-1.5B-Base)",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b-smoke",
        family="hybrid",
        n_layers=2,
        d_model=64,
        n_heads=5,  # keep the odd head count: FairKV's balanced-impossible case
        n_kv_heads=5,
        head_dim=8,
        d_ff=128,
        vocab_size=256,
        sliding_window=16,
        ssm=SSMConfig(state_size=4, num_heads=5, head_dim=8, chunk_size=8),
        source="reduced",
    )


register("hymba-1.5b", full, smoke)
