"""granite-3-2b — dense, 40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.

[hf:ibm-granite/granite-3.0-2b-base; hf]
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b",
        family="dense",
        n_layers=40,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=49155,
        tie_embeddings=True,
        shape_skips={"long_500k": FULL_ATTENTION_SKIP},
        source="hf:ibm-granite/granite-3.0-2b-base",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        tie_embeddings=True,
        shape_skips={"long_500k": FULL_ATTENTION_SKIP},
        source="reduced",
    )


register("granite-3-2b", full, smoke)
