"""Config system: architecture configs, input-shape registry, arch registry.

Every assigned architecture is a `ModelConfig` registered under its public id
(``--arch <id>``).  Each arch also exposes a ``smoke()`` reduced variant of the
same family (same structural features, tiny dims) used by CPU tests.

Input shapes are the four assigned cells (train_4k / prefill_32k / decode_32k /
long_500k); each arch advertises which cells apply to it (`shape_skips`).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List

# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_train(self) -> bool:
        return self.kind == "train"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    d_expert: int = 0  # per-expert FFN hidden size
    router_aux_coef: float = 0.01
    # per-expert token capacity = capacity_factor * T * top_k / E; overflow
    # tokens are dropped (GShard semantics).  Set to num_experts for no drops.
    capacity_factor: float = 1.25
    # beyond-paper: FairKV-style expert balancing (replicate hot experts)
    balance_experts: bool = False


@dataclass(frozen=True)
class SSMConfig:
    state_size: int = 0  # N (SSD state dim)
    num_heads: int = 0  # SSD heads
    head_dim: int = 0  # P (channels per head)
    n_groups: int = 1  # B/C groups (Mamba2 default: 1, shared across heads)
    chunk_size: int = 256
    conv_width: int = 4
    expand: int = 2

    @property
    def d_inner(self) -> int:
        return self.num_heads * self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """Architecture config.  Field names follow the assignment table."""

    name: str
    family: str  # dense | moe | vlm | hybrid | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int  # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # attention features
    qkv_bias: bool = False
    logit_softcap: float = 0.0  # gemma2 final-logit softcap
    attn_softcap: float = 0.0  # gemma2 attention softcap
    sliding_window: int = 0  # >0: local attention window
    local_global_alternate: bool = False  # gemma2: even layers local, odd global
    rope_theta: float = 10_000.0

    # norm / act
    rms_eps: float = 1e-6
    tie_embeddings: bool = False

    # mixture-of-experts (family == "moe")
    moe: MoEConfig = field(default_factory=MoEConfig)

    # state-space (family in {"ssm", "hybrid"})
    ssm: SSMConfig = field(default_factory=SSMConfig)

    # enc-dec (family == "audio")
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq_len: int = 0  # stub-frontend frame count

    # vlm (family == "vlm")
    is_vlm: bool = False
    num_image_tokens: int = 0  # stub-frontend patch-embedding count

    # which shape cells are skipped, with reasons (DESIGN.md §4)
    shape_skips: Dict[str, str] = field(default_factory=dict)

    source: str = ""  # public provenance

    # ---- derived -----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding/head rows padded to a multiple of 128 so the vocab dim
        shards on any mesh axis (MaxText-style).  Logits over pad ids are
        ignored by the loss (labels < vocab_size) and sliced off at serving
        argmax."""
        return -(-self.vocab_size // 128) * 128

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.n_heads == 0

    def layer_is_local(self, layer_idx: int) -> bool:
        """gemma2-style alternation: even layers sliding-window, odd global."""
        if self.sliding_window <= 0:
            return False
        if self.local_global_alternate:
            return layer_idx % 2 == 0
        return True

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, L = self.d_model, self.n_layers
        n = 0
        # embeddings (+ untied head)
        n += self.vocab_size * d
        if not self.tie_embeddings:
            n += self.vocab_size * d
        per_layer = 0
        if not self.attention_free:
            qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
            if self.qkv_bias:
                qkv += (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
            per_layer += qkv + self.n_heads * self.head_dim * d
        if self.moe.num_experts > 0:
            per_layer += self.moe.num_experts * 3 * d * self.moe.d_expert
            per_layer += d * self.moe.num_experts  # router
        elif self.d_ff > 0:
            per_layer += 3 * d * self.d_ff  # SwiGLU
        if self.ssm.state_size > 0:
            s = self.ssm
            # in_proj (z, x, B, C, dt) + out_proj + conv + A/D
            per_layer += d * (2 * s.d_inner + 2 * s.n_groups * s.state_size + s.num_heads)
            per_layer += s.d_inner * d
            per_layer += s.conv_width * (s.d_inner + 2 * s.n_groups * s.state_size)
            per_layer += 2 * s.num_heads
        per_layer += 2 * d  # 2 RMSNorm scales
        n += L * per_layer
        if self.is_encoder_decoder:
            # encoder layers: self-attn + FFN; decoder already counted above,
            # add cross-attention for decoder layers
            enc_layer = (
                d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
                + self.n_heads * self.head_dim * d
                + 3 * d * self.d_ff
                + 2 * d
            )
            n += self.n_encoder_layers * enc_layer
            n += L * (d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
                      + self.n_heads * self.head_dim * d + d)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts instead of all)."""
        if self.moe.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        unused = (self.moe.num_experts - self.moe.top_k) * 3 * self.d_model * self.moe.d_expert
        return full - self.n_layers * unused

    def applicable_shapes(self) -> List[InputShape]:
        return [s for k, s in SHAPES.items() if k not in self.shape_skips]

    def with_overrides(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[arch_id] = full
    _SMOKE[arch_id] = smoke


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def get_smoke_config(arch_id: str) -> ModelConfig:
    if arch_id not in _SMOKE:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_SMOKE)}")
    return _SMOKE[arch_id]()


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


FULL_ATTENTION_SKIP = (
    "long_500k needs sub-quadratic attention; this arch is pure full attention "
    "(see DESIGN.md §4)"
)
