"""whisper-small — audio enc-dec, 12L d_model=768 12H (kv=12 = MHA) d_ff=3072
vocab=51865, conv frontend (stub).  [arXiv:2212.04356; unverified]

Enc-dec: 12 encoder + 12 decoder layers.  Per assignment spec the conv frontend
is a STUB — ``input_specs()`` provides precomputed frame embeddings
(1500 frames x d_model, i.e. 30 s of audio after the 2x-stride conv stem).
Decode shapes lower the *decoder* step (self-attn KV cache + static cross-attn
KV from the encoder).
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=51865,
        is_encoder_decoder=True,
        n_encoder_layers=12,
        encoder_seq_len=1500,
        tie_embeddings=True,
        shape_skips={"long_500k": FULL_ATTENTION_SKIP},
        source="arXiv:2212.04356 (openai/whisper-small; conv stem stubbed)",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        is_encoder_decoder=True,
        n_encoder_layers=2,
        encoder_seq_len=32,
        tie_embeddings=True,
        shape_skips={"long_500k": FULL_ATTENTION_SKIP},
        source="reduced",
    )


register("whisper-small", full, smoke)
