"""qwen1.5-110b — dense, 80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.

QKV bias (the Qwen1.5 family signature).  [hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b",
        family="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=49152,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        shape_skips={"long_500k": FULL_ATTENTION_SKIP},
        source="hf:Qwen/Qwen1.5-110B (family config per hf:Qwen/Qwen1.5-0.5B)",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        head_dim=8,
        d_ff=128,
        vocab_size=256,
        qkv_bias=True,
        shape_skips={"long_500k": FULL_ATTENTION_SKIP},
        source="reduced",
    )


register("qwen1.5-110b", full, smoke)
