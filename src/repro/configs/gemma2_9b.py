"""gemma2-9b — dense, 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.

Local+global alternating attention, logit softcap.  [arXiv:2408.00118; hf]
long_500k runs: the alternation makes half the layers sliding-window, and decode
with a 500k KV is O(S)/step; see DESIGN.md §4.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        logit_softcap=30.0,
        attn_softcap=50.0,
        sliding_window=4096,
        local_global_alternate=True,
        tie_embeddings=True,
        source="arXiv:2408.00118 (google/gemma-2-9b)",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        logit_softcap=30.0,
        attn_softcap=50.0,
        sliding_window=16,
        local_global_alternate=True,
        tie_embeddings=True,
        source="reduced",
    )


register("gemma2-9b", full, smoke)
