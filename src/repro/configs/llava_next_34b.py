"""llava-next-34b — VLM backbone, 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000, anyres tiling.  [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

Per assignment spec, the modality frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (num_image_tokens x d_model) that are concatenated
ahead of the text tokens; only the transformer backbone is modeled.
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        family="vlm",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab_size=64000,
        is_vlm=True,
        num_image_tokens=2880,  # anyres: 5 tiles x 576 patches
        shape_skips={"long_500k": FULL_ATTENTION_SKIP},
        source="hf:llava-hf/llava-v1.6-34b-hf (backbone; frontend stubbed)",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        is_vlm=True,
        num_image_tokens=16,
        shape_skips={"long_500k": FULL_ATTENTION_SKIP},
        source="reduced",
    )


register("llava-next-34b", full, smoke)
