"""granite-moe-1b-a400m — MoE, 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,
        vocab_size=49155,
        tie_embeddings=True,
        moe=MoEConfig(num_experts=32, top_k=8, d_expert=512, balance_experts=True),
        shape_skips={"long_500k": FULL_ATTENTION_SKIP},
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=32,
        vocab_size=256,
        tie_embeddings=True,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=32, balance_experts=True),
        shape_skips={"long_500k": FULL_ATTENTION_SKIP},
        source="reduced",
    )


register("granite-moe-1b-a400m", full, smoke)
