"""mamba2-1.3b — pure SSM (attn-free), 48L d_model=2048 d_ff=0 vocab=50280,
ssm_state=128, SSD (state-space duality).  [arXiv:2405.21060; unverified]

FairKV is inapplicable (no KV cache / attention heads) — the arch is implemented
without the technique; see DESIGN.md §4.
"""
from repro.configs.base import ModelConfig, SSMConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=0,
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50280,
        tie_embeddings=True,
        # mamba2-1.3b: expand=2 -> d_inner=4096, P=64 -> 64 heads, N=128
        ssm=SSMConfig(state_size=128, num_heads=64, head_dim=64, chunk_size=256,
                      conv_width=4, expand=2),
        source="arXiv:2405.21060 (state-spaces/mamba2-1.3b)",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=256,
        tie_embeddings=True,
        ssm=SSMConfig(state_size=8, num_heads=4, head_dim=8, chunk_size=8,
                      conv_width=4, expand=2),
        source="reduced",
    )


register("mamba2-1.3b", full, smoke)
