"""Figure 9 (repo extension): paged decode — gather vs native kernel.

The gather-based paged decode (`kernels/paged_decode.py`) materializes each
row's blocks into full capacity-sized ``(S, B, C, Dh)`` contiguous views
every decode step: it reads the allocated blocks, *writes* ``S·B·C``
columns, and the slot kernel reads them back — slot-cache-scale HBM traffic
at the hottest point of the stack, no matter how little the compression
retained.  The native kernel (`kernels/paged_fairkv_decode.py`,
``impl="pallas"``) consumes the pools and block tables directly, so its
HBM→VMEM traffic is proportional to the **allocated blocks** — the realized
retained lengths FairKV balances (DESIGN.md §11).

This container has no TPU, so the committed numbers come from an explicit
HBM-bytes model evaluated on *measured* realized lengths (the real
Ada-SnapKV selection at paper-like operating points, placed by the
fairkv_dp planner):

- ``native_bytes``  = K+V reads of every owned (layer, slot, row)'s
  allocated blocks (one-block floor included).
- ``gather_bytes``  = the same block reads, plus writing the capacity-sized
  views, plus the slot kernel re-reading them (unowned (slot, row) pairs
  pay full capacity too — the gather cannot skip them).

Modeled decode throughput at a reference HBM bandwidth turns the byte ratio
into tokens/step-time: ``tokens_per_step_gain = gather_bytes /
native_bytes`` (the batch is identical on both sides, so the throughput
gain is exactly the byte ratio).  The acceptance gate is native >= 1.2x at
C >= 1024 under ``REPRO_BENCH_SMOKE=0`` — the full-size conditions are
recorded in the metrics dict (`conditions`) and asserted when not smoke.

Ride-alongs keep the model honest on CPU: an interpret-mode parity check of
the native kernel against ``ref.paged_fairkv_decode_ref`` on a random paged
layer, and a wall-clock sanity timing of the jnp vs gather dispatch impls.

Returns a metrics dict (recorded by ``run.py`` — ``BENCH.json`` by
default; the committed ``REPRO_BENCH_SMOKE=0`` run lives in
``BENCH_pr5.json``).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import realized_lengths
from benchmarks.fig7_paged_memory import paged_row_blocks
from repro.api import PlannerConfig, build_plan, profile_from_lengths
from repro.kernels import ops as K

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")

# paper-ish operating points (trimmed under smoke); alpha_max * budget is
# the static capacity C, so ratios 0.05 / 0.10 land at C = 1640 / 3277 for
# T = 8192 — the C >= 1024 regime the acceptance gate reads
N_LAYERS = 4 if SMOKE else 8
N_HEADS = 8
N_SHARDS = 4
T = 2048 if SMOKE else 8192
BATCH = 8
BLOCK_SIZE = 16
ALPHA_MAX = 4.0
RATIOS = [0.05] if SMOKE else [0.02, 0.05, 0.10]
HEAD_SKEW = 1.0  # Ada-SnapKV-style imbalanced profile
HBM_GBPS = 819.0  # reference bandwidth (v5e-class), for step-time scaling
DTYPE_BYTES = 2  # bf16 serving dtype


def byte_model(ratio: float) -> dict:
    """gather vs native HBM bytes per decode step at one compression ratio,
    on realized Ada-SnapKV lengths placed by the fairkv_dp planner."""
    budget = max(8, int(round(ratio * T)))
    lengths = realized_lengths(N_LAYERS, N_HEADS, budget, BATCH, T=T,
                               head_skew=HEAD_SKEW, policy="ada_snapkv",
                               alpha_max=ALPHA_MAX)
    prof = profile_from_lengths(lengths)
    plan = build_plan(prof, N_SHARDS, PlannerConfig(
        mode="fairkv_dp", extra_copies=4, batch_cap=BATCH))
    S = plan.n_shards * plan.slots_per_shard
    cap = int(round(ALPHA_MAX * budget))
    Dh = 64
    # allocated blocks over all (layer, slot, row) under plan ownership
    alloc_blocks = int(paged_row_blocks(lengths, plan, BLOCK_SIZE).sum())
    alloc_tokens = alloc_blocks * BLOCK_SIZE
    view_tokens = N_LAYERS * S * BATCH * cap  # what the gather materializes
    kv = 2 * DTYPE_BYTES * Dh  # K + V bytes per token column
    native_bytes = kv * alloc_tokens
    gather_bytes = kv * (alloc_tokens + 2 * view_tokens)
    gain = gather_bytes / native_bytes
    step_us = lambda b: b / (HBM_GBPS * 1e9) * 1e6
    return {
        "ratio": budget / T, "budget": budget, "capacity": cap,
        "alloc_tokens": alloc_tokens, "view_tokens": view_tokens,
        "native_bytes": native_bytes, "gather_bytes": gather_bytes,
        "native_step_us": step_us(native_bytes),
        "gather_step_us": step_us(gather_bytes),
        "tokens_per_step_gain": gain,
    }


def interpret_parity() -> float:
    """Native-kernel interpret run vs the jnp oracle on a random paged
    layer (`repro.paging.testing.make_paged_layer`, the construction the
    parity tests gate) — the check the kernels-interpret CI job runs in
    force."""
    from repro.paging.testing import make_paged_layer
    rng = np.random.default_rng(0)
    S, B, G, Dh, C, bs = 4, 2, 4, 64, 128, 16
    kp, vp, pos, table, lens = make_paged_layer(rng, S, B, C, bs, Dh)
    q = jnp.asarray(rng.normal(size=(B, S, G, Dh)), jnp.float32)
    args = (q, kp, vp, pos, table, lens, C)
    ref = K.paged_fairkv_decode(*args, impl="jnp")
    out = K.paged_fairkv_decode(*args, impl="pallas", interpret=True)
    return float(jnp.abs(out - ref).max())


def cpu_wall_us(impl: str, iters: int = 20) -> float:
    """Wall-clock of one jitted paged decode on CPU (sanity telemetry; the
    byte model above is the committed signal — CPU has no HBM hierarchy)."""
    rng = np.random.default_rng(1)
    S, B, G, Dh, C, bs = 8, 4, 4, 64, 256 if SMOKE else 512, 16
    M = -(-C // bs)
    N = S * B * M + 1
    lens = jnp.asarray(rng.integers(1, C + 1, size=(S, B)), jnp.int32)
    table = jnp.asarray(
        1 + np.arange(S * B * M).reshape(S, B, M), jnp.int32)
    kp = jnp.asarray(rng.normal(size=(N, bs, Dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(N, bs, Dh)), jnp.float32)
    pos = jnp.zeros((N, bs), jnp.int32)
    q = jnp.asarray(rng.normal(size=(B, S, G, Dh)), jnp.float32)

    fn = jax.jit(lambda *a: K.paged_fairkv_decode(*a, C, impl=impl))
    args = (q, kp, vp, pos, table, lens)
    fn(*args).block_until_ready()  # compile outside the timed loop
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def main():
    metrics = {
        "conditions": {
            "smoke": SMOKE, "n_layers": N_LAYERS, "n_heads": N_HEADS,
            "n_shards": N_SHARDS, "T": T, "batch": BATCH,
            "block_size": BLOCK_SIZE, "alpha_max": ALPHA_MAX,
            "head_skew": HEAD_SKEW, "policy": "ada_snapkv",
            "hbm_gbps": HBM_GBPS, "dtype_bytes": DTYPE_BYTES,
        },
        "model": [],
    }
    for ratio in RATIOS:
        t0 = time.time()
        r = byte_model(ratio)
        metrics["model"].append(r)
        print(f"fig9/model/ratio_{r['ratio']:.3f},"
              f"{(time.time() - t0) * 1e6:.0f},"
              f"C={r['capacity']};gather_MB={r['gather_bytes'] / 1e6:.1f};"
              f"native_MB={r['native_bytes'] / 1e6:.1f};"
              f"tokens_per_step_gain={r['tokens_per_step_gain']:.2f}")
    big = [r for r in metrics["model"] if r["capacity"] >= 1024]
    if big:
        metrics["min_gain_at_C_ge_1024"] = min(
            r["tokens_per_step_gain"] for r in big)
        print(f"fig9/gain_at_C_ge_1024,0,"
              f"min={metrics['min_gain_at_C_ge_1024']:.2f}")
        if not SMOKE:
            assert metrics["min_gain_at_C_ge_1024"] >= 1.2, metrics

    err = interpret_parity()
    metrics["interpret_max_err"] = err
    print(f"fig9/interpret_parity,0,max_err={err:.2e}")
    assert err < 1e-5, err

    wall = {impl: cpu_wall_us(impl) for impl in ("jnp", "gather")}
    metrics["cpu_wall_us"] = wall
    print(f"fig9/cpu_wall,0," + ";".join(
        f"{k}={v:.0f}us" for k, v in wall.items()))
    return metrics


if __name__ == "__main__":
    main()
