"""Figure 5: utilization vs copied-head count CH (the fair-copying budget).

Paper: large gains from the first few copies, diminishing after.  We sweep
CH ∈ {0, 1, 2, 3, 4, 8} at TP=8 on the 70B-like model.
"""
from __future__ import annotations

from benchmarks.common import (
    DecodeTimeModel,
    SIM_MODELS,
    realized_lengths,
    v5e_overhead_tokens,
)
from repro.api import PlannerConfig, build_plan, profile_from_lengths

MODEL = "llama70b-like(qwen1.5-110b)"


def run(budgets=(128, 256, 512, 1024), chs=(0, 1, 2, 3, 4, 8), tp: int = 8,
        batch: int = 32, layers_cap: int = 8) -> list:
    dims = SIM_MODELS[MODEL]
    L = min(dims["n_layers"], layers_cap)
    scale = dims["n_layers"] / L
    params_bytes = 2.0 * (dims["d_model"] * dims["d_ff"] * 3
                          + dims["d_model"] * dims["d_model"] * 2
                          ) * dims["n_layers"]
    rows = []
    for budget in budgets:
        lengths = realized_lengths(L, dims["n_heads"], budget, batch,
                                   head_skew=1.0, head_seed=7)
        prof = profile_from_lengths(lengths)
        ovh = v5e_overhead_tokens(dims["d_model"], dims["d_ff"],
                                  dims["n_layers"], batch, tp,
                                  dims["head_dim"], params_bytes / tp) / scale
        tm = DecodeTimeModel(overhead_tokens=ovh)
        utils = {}
        slots = max(1, -(-dims["n_heads"] // tp)) + 1
        for ch in chs:
            plan = build_plan(prof, tp, PlannerConfig(
                mode="fairkv_dp", extra_copies=ch, slots_per_shard=slots,
                fill_empty_slots=False))
            utils[ch] = tm.utilization(plan, lengths)
        rows.append({"name": f"fig5/budget{budget}/tp{tp}", "utils": utils})
    return rows


def main():
    for r in run():
        parts = ";".join(f"ch{c}={u:.3f}" for c, u in r["utils"].items())
        print(f"{r['name']},0,{parts}")


if __name__ == "__main__":
    main()
