"""Figure 3: decode-throughput gain of FairKV over SHA.

Paper: up to 1.66× on LLaMA-70B, gains growing with TP size and (mostly)
with budget.  Same simulation substrate as table2; gain = throughput ratio
FairKV-DP / SHA (throughput ∝ batch / max-shard-time).
"""
from __future__ import annotations


from benchmarks.common import (
    DecodeTimeModel,
    SIM_MODELS,
    make_plans,
    realized_lengths,
    v5e_overhead_tokens,
)


def run(budgets=(128, 256, 512, 1024), tps=(4, 8), batch: int = 32,
        layers_cap: int = 8, ch: int = 4) -> list:
    rows = []
    for model_name, dims in SIM_MODELS.items():
        L = min(dims["n_layers"], layers_cap)
        scale = dims["n_layers"] / L
        params_bytes = 2.0 * (dims["d_model"] * dims["d_ff"] * 3
                              + dims["d_model"] * dims["d_model"] * 2
                              ) * dims["n_layers"]
        for budget in budgets:
            lengths = realized_lengths(L, dims["n_heads"], budget, batch,
                                       head_skew=1.0, head_seed=7)
            for tp in tps:
                plans = make_plans(lengths, tp, ch=ch)
                ovh = v5e_overhead_tokens(
                    dims["d_model"], dims["d_ff"], dims["n_layers"], batch,
                    tp, dims["head_dim"], params_bytes / tp) / scale
                tm = DecodeTimeModel(overhead_tokens=ovh)
                thr = {k: tm.throughput(p, lengths) for k, p in plans.items()}
                rows.append({
                    "name": f"fig3/{model_name}/budget{budget}/tp{tp}",
                    "gain_dp": thr["fairkv_dp"] / thr["sha"],
                    "gain_nodp": thr["fairkv_nodp"] / thr["sha"],
                })
    return rows


def main():
    best = 0.0
    for r in run():
        best = max(best, r["gain_dp"])
        print(f"{r['name']},0,gain_dp={r['gain_dp']:.3f};"
              f"gain_nodp={r['gain_nodp']:.3f}")
    print(f"fig3/max_gain,0,gain={best:.3f}")


if __name__ == "__main__":
    main()
