"""Roofline analysis from the dry-run artifacts (assignment §Roofline).

Per (arch × shape × mesh) cell:
    compute term    = HLO_FLOPs_per_device / 197e12       [bf16 peak/chip]
    memory term     = HLO_bytes_per_device / 819e9        [HBM bw/chip]
    collective term = coll_bytes_per_device / (3 · 50e9)  [~3 ICI links/chip]

plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per step for trains,
2·N·tokens for decode/prefill, and the useful-compute ratio
MODEL_FLOPS / (HLO_FLOPs · n_devices).

Caveats recorded with each row (DESIGN.md §5):
- cost_analysis counts while-loop bodies once; cells whose step contains
  scans (flash-prefill chunks, SSD chunks) get an analytic correction using
  the known trip counts (``while_flops_scale``).
- the FairKV effective memory term scales the KV-read share by the expected
  retained/capacity ratio and the plan's balance E.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import List


PEAK = 197e12
HBM = 819e9
ICI = 50e9 * 3  # ~3 links per chip on a 2D torus axis pair

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


@dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    kind: str
    flops: float
    bytes_: float
    coll_bytes: float
    compute_s: float
    memory_s: float  # lower bound: argument+output bytes (true HBM traffic
                     # for decode; weights/cache are read exactly once)
    memory_s_hi: float  # upper bound: HLO bytes-accessed (counts every
                        # fusion operand; inflated by CPU bf16 emulation)
    coll_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    peak_gb: float
    status: str
    note: str = ""


def model_flops_for(arch: str, shape: str) -> float:
    from repro.configs import SHAPES, get_config
    cfg = get_config(arch)
    sh = SHAPES[shape]
    n_active = cfg.active_param_count()
    if sh.kind == "train":
        tokens = sh.seq_len * sh.global_batch
        return 6.0 * n_active * tokens
    if sh.kind == "prefill":
        tokens = sh.seq_len * sh.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per row
    return 2.0 * n_active * sh.global_batch


def scan_correction(rec: dict) -> float:
    """Scale factor for while-body flops (trip counts known per step kind)."""
    from repro.configs import SHAPES, get_config
    cfg = get_config(rec["arch"])
    sh = SHAPES[rec["shape"]]
    if not rec.get("while_bodies"):
        return 1.0
    if sh.kind == "train" or sh.kind == "prefill":
        # flash K-chunks (chunk=1024) and/or SSD chunks (cfg.ssm.chunk_size)
        trips = max(sh.seq_len // 1024, 1)
        if cfg.ssm.state_size:
            trips = max(trips, sh.seq_len // max(cfg.ssm.chunk_size, 1))
        return float(trips)
    return 1.0


def load_cells(dryrun_dir: str = DRYRUN_DIR) -> List[Cell]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("status") == "skipped":
            cells.append(Cell(rec["arch"], rec["shape"], rec["mesh"], "-",
                              0, 0, 0, 0, 0, 0, 0, "-", 0, 0, 0, "skipped",
                              rec.get("reason", "")[:60]))
            continue
        if rec.get("status") != "ok":
            cells.append(Cell(rec["arch"], rec["shape"], rec["mesh"], "-",
                              0, 0, 0, 0, 0, 0, 0, "-", 0, 0, 0, "fail",
                              rec.get("error", "")[:60]))
            continue
        n_dev = 512 if rec["mesh"] == "multi" else 256
        flops = float(rec["cost"]["flops_per_device"] or 0)
        bytes_ = float(rec["cost"]["bytes_per_device"] or 0)
        coll = sum(c["bytes"] for c in rec.get("collectives", {}).values())
        note = ""
        corr = scan_correction(rec)
        if corr > 1.0 and rec.get("while_bodies"):
            body_coll = sum(b["bytes"] for b in rec["while_bodies"].values())
            coll += body_coll * (corr - 1)
            note = f"scan-corrected x{corr:.0f} (flash/SSD chunk bodies)"
        mf = model_flops_for(rec["arch"], rec["shape"])
        # flops correction for scan bodies: bound via analytic model-flops
        flops_eff = max(flops, mf / n_dev / 3.0) if corr > 1 else flops
        io_bytes = (rec["memory"]["argument_bytes"]
                    + rec["memory"]["output_bytes"]
                    - rec["memory"]["alias_bytes"])
        # train/prefill flow activations through HBM several times; decode
        # reads args once.  traffic multiplier by step kind (documented).
        traffic = {"decode": 1.0, "prefill": 2.0, "train": 3.0}[rec["kind"]]
        mem_lo = io_bytes * traffic
        cs, ms, os_ = flops_eff / PEAK, mem_lo / HBM, coll / ICI
        ms_hi = bytes_ / HBM
        dom = max((("compute", cs), ("memory", ms), ("collective", os_)),
                  key=lambda kv: kv[1])[0]
        cells.append(Cell(
            rec["arch"], rec["shape"], rec["mesh"], rec["kind"],
            flops_eff, mem_lo, coll, cs, ms, ms_hi, os_, dom, mf,
            mf / max(flops_eff * n_dev, 1e-9),
            rec["memory"]["peak_per_device_gb"], "ok", note))
    return cells


def render_markdown(cells: List[Cell]) -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s (lo..hi) | "
        "collective s | dominant | MODEL_FLOPS | useful ratio | "
        "peak GB/dev | note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.status != "ok":
            lines.append(f"| {c.arch} | {c.shape} | {c.mesh} | - | - | - | "
                         f"{c.status.upper()} | - | - | - | {c.note} |")
            continue
        lines.append(
            f"| {c.arch} | {c.shape} | {c.mesh} | {c.compute_s:.2e} | "
            f"{c.memory_s:.2e}..{c.memory_s_hi:.2e} | {c.coll_s:.2e} | "
            f"**{c.dominant}** | "
            f"{c.model_flops:.2e} | {c.useful_ratio:.2f} | {c.peak_gb:.2f} | "
            f"{c.note} |")
    return "\n".join(lines)


def main():
    cells = load_cells()
    ok = [c for c in cells if c.status == "ok"]
    print(f"roofline/cells,0,ok={len(ok)};skipped="
          f"{sum(c.status == 'skipped' for c in cells)};fail="
          f"{sum(c.status == 'fail' for c in cells)}")
    for c in ok:
        step_time = max(c.compute_s, c.memory_s, c.coll_s)
        print(f"roofline/{c.arch}/{c.shape}/{c.mesh},0,"
              f"dominant={c.dominant};step_s={step_time:.3e};"
              f"useful={c.useful_ratio:.2f}")
    out = os.path.join(DRYRUN_DIR, "..", "roofline.md")
    with open(out, "w") as f:
        f.write("# Roofline table (from dry-run artifacts)\n\n")
        f.write(render_markdown(cells))
        f.write("\n")


if __name__ == "__main__":
    main()
