"""Figure 12 (repo extension): quantized KV storage — bytes vs quality.

PR 9 stores the paged K/V pools in int8 (fp8 where the jax build supports
it) with per-block fp32 scales (DESIGN.md §15).  Decode on the HBM-bound
accelerator path is bytes-limited: per step it streams every retained KV
block once, so shrinking the payload dtype converts directly into decode
bandwidth and — through bytes-aware pool sizing — into batch capacity.
Three measurements, all against the fp32 storage baseline:

1. **Modeled decode HBM bytes per token** — the bytes one (layer, slot,
   row) at capacity ``C`` streams per decode step (payload blocks + scale
   entries + the position columns both arms read), from the same
   ``block_hbm_bytes`` unit the admission path charges.  Gate: int8
   reduction >= 1.7x at every C >= 1024.

2. **Quality proxy (Table 1 machinery)** — build a paged layer with
   Ada-SnapKV realized lengths (`benchmarks.common.realized_lengths`),
   quantize it with the shared fixture helper, and compare the decode
   reference output against fp32 storage with `cosine_similarity` — the
   same metric Table 1 uses for retained-profile agreement.  Gate: int8
   cosine >= 0.98.

3. **Equal-HBM max batch (fig7 extension)** — rerun fig7's analytic sweep
   with the pool sized in *bytes* instead of fp32 blocks: at the byte
   budget the slot cache spends on BATCH fp32 rows, the int8 pool admits
   ~4x the blocks, so the sustainable batch beats the committed
   ``BENCH_pr3.json`` paged numbers at every compression ratio.  Gate:
   int8 batch > fp32 batch for every ratio.

``REPRO_BENCH_SMOKE=1`` shrinks the sweep for CI; the gates still run.

Returns a metrics dict (recorded by ``run.py``; the PR-9 committed copy
lives in ``BENCH_pr9.json``).
"""
from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import realized_lengths
from benchmarks.fig7_paged_memory import (
    BATCH,
    BLOCK_SIZE,
    HEAD_SKEW,
    N_HEADS,
    N_LAYERS,
    N_SHARDS,
    RATIOS,
    T,
    paged_row_blocks,
)
from repro.api import PlannerConfig, build_plan, profile_from_lengths
from repro.core import cosine_similarity
from repro.kernels.ref import paged_fairkv_decode_ref
from repro.paging.block_pool import blocks_for_tokens
from repro.paging.kvquant import KIND_FP8, KIND_INT8, fp8_supported
from repro.paging.paged_cache import block_hbm_bytes
from repro.paging.testing import make_paged_layer, quantize_paged_layer

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")

KV_DTYPE = "int8"  # storage dtype this suite measures (run.py metadata)

DH = 128  # modeled head dim; both arms share it, the ratio does not
CAPACITIES = [1024, 2048] if SMOKE else [1024, 2048, 4096, 8192]

# quality fixture: paged layer with Ada-SnapKV ragged lengths
Q_SLOTS = 8
Q_ROWS = 2 if SMOKE else 4
Q_GROUP = 2
Q_DH = 64
Q_CAP = 1024
Q_BUDGET = 64 if SMOKE else 128


def decode_bytes_per_step(capacity: int, dtype, quantized: bool) -> int:
    """HBM bytes one (layer, slot, row) at ``capacity`` retained tokens
    streams per decode step: every payload block once (plus its two fp32
    scale entries when quantized, via the same `block_hbm_bytes` unit the
    admission path charges) and the int32 position column both arms read."""
    blocks = blocks_for_tokens(capacity, BLOCK_SIZE)
    payload = blocks * block_hbm_bytes(BLOCK_SIZE, DH, dtype, quantized)
    positions = blocks * BLOCK_SIZE * 4  # pos_pool, dtype-independent
    return payload + positions


def bytes_sweep() -> dict:
    """Bytes-per-token table and the C >= 1024 int8 reduction gate."""
    rows = []
    for cap in CAPACITIES:
        fp32 = decode_bytes_per_step(cap, jnp.float32, False)
        int8 = decode_bytes_per_step(cap, jnp.int8, True)
        rows.append({
            "capacity": cap,
            "fp32_bytes_per_token": fp32 / cap,
            "int8_bytes_per_token": int8 / cap,
            "reduction": fp32 / int8,
        })
    reductions = [r["reduction"] for r in rows if r["capacity"] >= 1024]
    return {"per_capacity": rows,
            "min_reduction_at_C_ge_1024": min(reductions)}


def quality_fixture():
    """Paged layer + query batch with Ada-SnapKV realized lengths."""
    rng = np.random.default_rng(9)
    lengths = realized_lengths(1, Q_SLOTS, Q_BUDGET, Q_ROWS, T=2048,
                               head_skew=HEAD_SKEW, policy="ada_snapkv",
                               alpha_max=4.0)[0]
    lengths = np.clip(lengths, 1, Q_CAP).astype(np.int32)
    layer = make_paged_layer(rng, Q_SLOTS, Q_ROWS, Q_CAP, BLOCK_SIZE, Q_DH,
                             lengths=lengths)
    q = jnp.asarray(rng.normal(size=(Q_ROWS, Q_SLOTS, Q_GROUP, Q_DH))
                    .astype(np.float32))
    return layer, q


def quality_cosine(layer, q, kind: int) -> float:
    """Cosine (Table 1 metric) of quantized-storage decode vs fp32."""
    k, v, pos, table, lengths = layer
    ref = paged_fairkv_decode_ref(q, k, v, pos, table, lengths, Q_CAP)
    kinds = np.full((Q_SLOTS,), kind, np.int32)
    kc, vc, ks, vs = quantize_paged_layer(k, v, table, kinds)
    out = paged_fairkv_decode_ref(q, kc, vc, pos, table, lengths, Q_CAP,
                                  k_scale=ks, v_scale=vs,
                                  kinds=jnp.asarray(kinds))
    return cosine_similarity(np.asarray(ref), np.asarray(out))


def equal_hbm_batch(ratio: float) -> dict:
    """fig7's analytic max batch with the pool sized in bytes per dtype."""
    budget = max(8, int(round(ratio * T)))
    alpha_max = 4.0
    lengths = realized_lengths(N_LAYERS, N_HEADS, budget, BATCH, T=T,
                               head_skew=HEAD_SKEW, policy="ada_snapkv",
                               alpha_max=alpha_max)
    prof = profile_from_lengths(lengths)
    plan = build_plan(prof, N_SHARDS, PlannerConfig(
        mode="fairkv_dp", extra_copies=4, batch_cap=BATCH))
    S = plan.n_shards * plan.slots_per_shard
    cap_blocks = blocks_for_tokens(int(round(alpha_max * budget)),
                                   BLOCK_SIZE)
    # equal HBM: the bytes the fp32 slot cache spends on BATCH rows
    fp32_block = block_hbm_bytes(BLOCK_SIZE, DH, jnp.float32, False)
    int8_block = block_hbm_bytes(BLOCK_SIZE, DH, jnp.int8, True)
    hbm_bytes = N_LAYERS * S * BATCH * cap_blocks * fp32_block
    mean_row = float(paged_row_blocks(lengths, plan, BLOCK_SIZE).mean())
    fp32_batch = int(hbm_bytes // (mean_row * fp32_block))
    int8_batch = int(hbm_bytes // (mean_row * int8_block))
    return {
        "budget": budget,
        "ratio": budget / T,
        "slot_batch": BATCH,
        "paged_fp32_batch": fp32_batch,
        "paged_int8_batch": int8_batch,
        "int8_gain_vs_slot": int8_batch / BATCH,
        "int8_gain_vs_paged_fp32": int8_batch / max(fp32_batch, 1),
        "mean_row_blocks": mean_row,
    }


def main():
    metrics = {"kv_dtype": KV_DTYPE, "block_size": BLOCK_SIZE,
               "head_dim": DH, "fp8_supported": fp8_supported()}

    # --- 1. modeled decode bytes --------------------------------------------
    t0 = time.time()
    metrics["bytes"] = bytes_sweep()
    red = metrics["bytes"]["min_reduction_at_C_ge_1024"]
    print(f"fig12/bytes,{(time.time() - t0) * 1e6:.0f},"
          f"min_reduction_at_C_ge_1024={red:.2f}")

    # --- 2. quality proxy ---------------------------------------------------
    t0 = time.time()
    layer, q = quality_fixture()
    cos = {"int8": quality_cosine(layer, q, KIND_INT8)}
    if fp8_supported():
        cos["fp8"] = quality_cosine(layer, q, KIND_FP8)
    metrics["cosine"] = cos
    print(f"fig12/quality,{(time.time() - t0) * 1e6:.0f},"
          + ";".join(f"cosine_{k}={v:.4f}" for k, v in cos.items()))

    # --- 3. equal-HBM max batch (fig7 extension) ----------------------------
    metrics["equal_hbm"] = []
    for ratio in RATIOS:
        t0 = time.time()
        r = equal_hbm_batch(ratio)
        metrics["equal_hbm"].append(r)
        print(f"fig12/max_batch/ratio_{r['ratio']:.3f},"
              f"{(time.time() - t0) * 1e6:.0f},"
              f"fp32_batch={r['paged_fp32_batch']};"
              f"int8_batch={r['paged_int8_batch']};"
              f"gain_vs_fp32={r['int8_gain_vs_paged_fp32']:.2f}")
    metrics["min_int8_gain_vs_paged_fp32"] = min(
        r["int8_gain_vs_paged_fp32"] for r in metrics["equal_hbm"])

    # --- gates (ISSUE 9 acceptance; pure math + deterministic compute, so
    # they hold under smoke too) ---------------------------------------------
    metrics["gate_bytes_reduction"] = bool(red >= 1.7)
    metrics["gate_cosine"] = bool(cos["int8"] >= 0.98)
    metrics["gate_equal_hbm"] = all(
        r["paged_int8_batch"] > r["paged_fp32_batch"]
        for r in metrics["equal_hbm"])
    assert metrics["gate_bytes_reduction"], metrics["bytes"]
    assert metrics["gate_cosine"], cos
    assert metrics["gate_equal_hbm"], metrics["equal_hbm"]
    print(f"fig12/gates,0,bytes={red:.2f}>=1.7;"
          f"cosine_int8={cos['int8']:.4f}>=0.98;equal_hbm=ok")
    return metrics


if __name__ == "__main__":
    main()
