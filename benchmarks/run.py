"""Benchmark driver: one suite per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (one per measured entity) and
writes a machine-readable summary (``BENCH.json`` by default — the git sha
recorded inside identifies the run, so the filename stays stable): per-suite
wall time, ok flag, whatever metrics dict the suite's ``main()`` returned,
plus the git sha — so the perf trajectory of this repo is diffable across
PRs instead of living in scrollback.

Suites live in a registry (name → module), so single-figure runs stop
paying for the full sweep::

    python benchmarks/run.py --list            # show suite names
    python benchmarks/run.py --only fig6       # just fig6
    python benchmarks/run.py --only fig1,fig3  # a comma-set
    python benchmarks/run.py --skip table3     # everything else
    python benchmarks/run.py --out ''          # disable the JSON artifact

Skipped suites are never imported, so their (potentially heavy) JAX
tracing cost is not paid either.
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import subprocess
import sys
import time
import traceback

# make ``import benchmarks.<suite>`` work however run.py is invoked
# (``python benchmarks/run.py`` puts benchmarks/ itself on sys.path, not
# the repo root that contains the package)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

# name -> module path; each module exposes main() (optionally returning a
# metrics dict for the JSON artifact).  Ordered as the paper presents them
# (cheap simulation suites first, end-to-end system last).
SUITES = {
    "table1": "benchmarks.table1_cosine_similarity",
    "table2": "benchmarks.table2_gpu_utilization",
    "fig1": "benchmarks.fig1_latency_linearity",
    "fig3": "benchmarks.fig3_throughput_gain",
    "fig4": "benchmarks.fig4_ablation",
    "fig5": "benchmarks.fig5_dp_size",
    "fig6": "benchmarks.fig6_continuous_throughput",
    "fig7": "benchmarks.fig7_paged_memory",
    "fig8": "benchmarks.fig8_fair_copying_tp",
    "fig9": "benchmarks.fig9_paged_kernel",
    "fig10": "benchmarks.fig10_goodput",
    "fig11": "benchmarks.fig11_prefix_reuse",
    "fig12": "benchmarks.fig12_quantized_kv",
    "fig13": "benchmarks.fig13_speculative",
    "table3": "benchmarks.table3_quality_proxy",
}


def _parse_names(value: str) -> list:
    names = [n.strip() for n in value.split(",") if n.strip()]
    unknown = [n for n in names if n not in SUITES]
    if unknown:
        raise SystemExit(
            f"unknown suite(s) {unknown}; known: {list(SUITES)}")
    return names


def select_suites(only: str = "", skip: str = "") -> list:
    """Resolve --only/--skip into an ordered suite-name list."""
    names = _parse_names(only) if only else list(SUITES)
    for n in (_parse_names(skip) if skip else []):
        if n in names:
            names.remove(n)
    return names


def git_sha() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "HEAD"], text=True,
            stderr=subprocess.DEVNULL).strip()
    except Exception:
        return "unknown"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--list", action="store_true",
                    help="print the registered suite names and exit")
    ap.add_argument("--only", default="",
                    help="comma-separated suites to run (default: all)")
    ap.add_argument("--skip", default="",
                    help="comma-separated suites to exclude")
    ap.add_argument("--out", default="BENCH.json",
                    help="machine-readable results path ('' disables); the "
                         "git sha inside the JSON identifies the run")
    args = ap.parse_args(argv)

    if args.list:
        for name, module in SUITES.items():
            print(f"{name}\t{module}")
        return

    names = select_suites(args.only, args.skip)
    if not names:
        raise SystemExit("no suites selected (--only/--skip removed all)")
    print("name,us_per_call,derived")
    failed = []
    report = {"git_sha": git_sha(),
              "started_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
              "suites": {}}
    for name in names:
        t0 = time.time()
        metrics = None
        kv_dtype = "fp32"
        try:
            module = importlib.import_module(SUITES[name])
            # storage dtype the suite measures (PR 9); fp32 unless declared
            kv_dtype = getattr(module, "KV_DTYPE", "fp32")
            metrics = module.main()
        except Exception:
            traceback.print_exc()
            failed.append(name)
        wall_us = (time.time() - t0) * 1e6
        print(f"{name}/_suite,{wall_us:.0f},ok={name not in failed}")
        entry = {"ok": name not in failed, "wall_us": wall_us,
                 "kv_dtype": kv_dtype}
        if isinstance(metrics, dict):
            entry["metrics"] = metrics
        report["suites"][name] = entry
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"# wrote {args.out}", file=sys.stderr)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
