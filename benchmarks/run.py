"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (one per measured entity).
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        fig1_latency_linearity,
        fig3_throughput_gain,
        fig4_ablation,
        fig5_dp_size,
        fig6_continuous_throughput,
        table1_cosine_similarity,
        table2_gpu_utilization,
        table3_quality_proxy,
    )
    print("name,us_per_call,derived")
    suites = [
        ("table1", table1_cosine_similarity.main),
        ("table2", table2_gpu_utilization.main),
        ("fig1", fig1_latency_linearity.main),
        ("fig3", fig3_throughput_gain.main),
        ("fig4", fig4_ablation.main),
        ("fig5", fig5_dp_size.main),
        ("fig6", fig6_continuous_throughput.main),
        ("table3", table3_quality_proxy.main),
    ]
    failed = []
    for name, fn in suites:
        t0 = time.time()
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failed.append(name)
        print(f"{name}/_suite,{(time.time() - t0) * 1e6:.0f},ok={name not in failed}")
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
