"""Figure 10 (repo extension): multi-tenant goodput — SLO-aware admission
vs FCFS.

*Goodput* is SLO-attained tokens per scheduler step: a token decoded for a
request whose TTFT already blew its priority class's target is throughput
but not goodput.  The frontend's SLO-aware controller (DESIGN.md §13)
raises goodput under overload three ways the FCFS baseline cannot:

- **priority scheduling** — interactive (class 0) requests jump the line
  and may preempt a decoding batch-class row, so the tightest SLOs are
  met first;
- **shedding** — a request queued past its class's ``shed_after_steps``
  is rejected instead of decoded: its SLO is already blown, so decoding
  it would burn rows that can still produce goodput;
- **tenant fairness** — deficit-round-robin token quotas keep one bursty
  tenant from starving the others into SLO misses.

The benchmark replays the SAME bursty three-tenant trace (bursts of
simultaneous arrivals overloading a 2-row engine, deterministic seed)
through two fresh engines — ``admission="slo"`` and ``admission="fcfs"``
— and compares goodput tokens/step and SLO attainment.  Both runs judge
attainment against identical priority classes, so the comparison isolates
the admission policy.  The run also self-checks the §13 observability
contract: the engine's Prometheus export must carry the per-tenant
``slo_attained_total`` / ``goodput_tokens_total`` and TTFT/ITL histogram
families.

Acceptance (``REPRO_BENCH_SMOKE=0``): SLO-aware goodput/step strictly
beats FCFS (gate ``goodput_gain > 1.0``; the committed run in
``BENCH_pr7.json`` records the realized margin).
"""
from __future__ import annotations

import os
import time

from repro.api import CompressionConfig, Engine, EngineConfig, PlannerConfig
from repro.api import SchedulerConfig, synthesize_requests
from repro.frontend import (
    FrontendConfig,
    FrontendScheduler,
    PriorityClass,
    run_frontend_trace,
)

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")

ARCH = "minitron-8b"
N_SHARDS = 4
ROWS = 2  # small batch → bursts genuinely overload the engine
GEN = 8
MIN_PROMPT, MAX_PROMPT = 8, 16
N_REQUESTS = 24 if SMOKE else 48
BURST = 12  # simultaneous arrivals per burst (6x the row capacity)
BURST_GAP = 10  # steps between bursts (far below a burst's drain time)
SEED = 7
# the aggressor-tenant shape: a best-effort batch tenant floods 3x the
# traffic of the latency-sensitive tenants.  FCFS head-of-line blocks
# interactive requests behind the flood; DRR quotas + priority admission
# + the preemption lever are exactly what rescues them.
TENANT_MIX = {"interactive": 1.0, "standard": 1.0, "batch": 3.0}
TENANT_PRIO = {"interactive": 0, "standard": 1, "batch": 2}
MAX_STEPS = 600
# every class carries a REAL latency target (batch included — the default
# batch class's 200-step target never bites at this trace length, which
# would hand FCFS free attainment for work it serves arbitrarily late),
# and a shed threshold just past it: a request still queued beyond its
# target is doomed, and decoding it burns rows that could be goodput.
# Both modes judge attainment against these same classes; only the SLO
# controller *acts* on them (shed / preempt / degrade).
CLASSES = (
    PriorityClass("interactive", 0, ttft_slo_steps=24, shed_after_steps=28,
                  preempt_below=True),
    PriorityClass("standard", 1, ttft_slo_steps=48, shed_after_steps=52),
    PriorityClass("batch", 2, ttft_slo_steps=110, degrade_floor=4),
)

# Prometheus families the §13 accounting contract promises per tenant
REQUIRED_FAMILIES = (
    "slo_attained_total", "slo_missed_total", "goodput_tokens_total",
    "frontend_ttft_steps_bucket", "frontend_itl_seconds_bucket",
    "frontend_admission_total",
)


def build_engine() -> Engine:
    cfg = EngineConfig.smoke(
        ARCH, n_shards=N_SHARDS, max_seq_len=MAX_PROMPT + GEN + 8,
        compression=CompressionConfig(
            policy="ada_snapkv", budget=16, alpha_max=2.0, obs_window=8,
            sink=2, decode_margin=GEN),
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=4,
                              batch_cap=ROWS),
        scheduler=SchedulerConfig(max_rows=ROWS, enable_replan=False))
    return Engine.build(cfg)


def bursty_trace(vocab_size: int):
    """Deterministic three-tenant trace, re-shaped into bursts of ``BURST``
    simultaneous arrivals every ``BURST_GAP`` steps (Poisson arrivals would
    spread the load; bursts are what make admission policy matter)."""
    reqs = synthesize_requests(
        N_REQUESTS, rate=1.0, vocab_size=vocab_size, min_prompt=MIN_PROMPT,
        max_prompt=MAX_PROMPT, max_new_tokens=GEN, seed=SEED,
        tenant_mix=TENANT_MIX, tenant_priorities=TENANT_PRIO)
    for i, r in enumerate(reqs):
        r.arrival_step = (i // BURST) * BURST_GAP
    return reqs


def run_mode(admission: str) -> dict:
    """One fresh engine + frontend over the shared trace."""
    eng = build_engine()
    fe = FrontendScheduler(
        eng._ensure_scheduler(),
        FrontendConfig(admission=admission, classes=CLASSES,
                       quantum_tokens=64, quota_cap_tokens=512))
    out = run_frontend_trace(fe, bursty_trace(eng.cfg.model.vocab_size),
                             max_steps=MAX_STEPS)
    out["prometheus"] = eng.metrics_prometheus()
    return out


def main():
    metrics = {
        "conditions": {
            "smoke": SMOKE, "arch": ARCH, "rows": ROWS, "gen": GEN,
            "n_requests": N_REQUESTS, "burst": BURST,
            "burst_gap": BURST_GAP, "seed": SEED,
            "tenant_priorities": TENANT_PRIO,
        },
    }
    results = {}
    for admission in ("fcfs", "slo"):
        t0 = time.time()
        out = run_mode(admission)
        prom = out.pop("prometheus")
        results[admission] = out
        metrics[admission] = {
            k: out[k] for k in
            ("steps", "finished", "rejected", "generated_tokens",
             "goodput_tokens", "goodput_tokens_per_step", "slo_attained",
             "slo_missed", "slo_attainment", "preemptions", "tenants")}
        print(f"fig10/{admission},{(time.time() - t0) * 1e6:.0f},"
              f"goodput_per_step={out['goodput_tokens_per_step']:.2f};"
              f"attainment={out['slo_attainment']:.2f};"
              f"rejected={out['rejected']};steps={out['steps']}")
        if admission == "slo":
            # §13 observability contract: per-tenant families in /metrics
            missing = [f for f in REQUIRED_FAMILIES
                       if f"{f}{{" not in prom]
            assert not missing, f"missing metric families: {missing}"
            assert 'tenant="interactive"' in prom, "tenant label missing"
            print("fig10/metrics_contract,0,families=ok")

    gain = (results["slo"]["goodput_tokens_per_step"]
            / max(results["fcfs"]["goodput_tokens_per_step"], 1e-9))
    metrics["goodput_gain"] = gain
    att = {m: results[m]["slo_attainment"] for m in results}
    print(f"fig10/goodput_gain,0,slo_over_fcfs={gain:.2f};"
          f"attainment_fcfs={att['fcfs']:.2f};attainment_slo={att['slo']:.2f}")
    for r in results.values():
        assert r["converged"], "trace did not converge within MAX_STEPS"
    if not SMOKE:
        assert gain > 1.0, (
            f"SLO-aware goodput must beat FCFS, got gain={gain:.3f}")
    return metrics


if __name__ == "__main__":
    main()
