"""Figure 8 (repo extension): Fair-Copying vs plain TP on the real engine.

The paper's headline claim — replicating memory-hot heads (Fair-Copying)
lifts multi-GPU decode throughput over plain tensor parallelism — measured
on the *system*, not the simulator: both arms drive the continuous-batching
engine through the `repro.api` facade with **per-model-shard admission**
(``SchedulerConfig.max_live_tokens_per_shard``, DESIGN.md §10).  Admission
is gated by the bottleneck shard, exactly as on a real mesh where one
device's memory is the resource that runs out:

The workload is HeadKV with a skewed per-head importance vector — the
BaKlaVa-style (arXiv:2502.13176) per-head budget allocation that makes TP
imbalanced in the first place: a few memory-hot heads pin several times
the KV of the cold ones.

- **plain TP** — SHA placement, single copy per head
  (``fill_empty_slots=False``): the heads the compression policy keeps
  long pile their KV onto whichever shard holds them, that shard's budget
  saturates first, and admission stalls with free rows still idle.
- **Fair-Copying** — ``fairkv_dp`` with extra copies on the same slot
  grid and the same measured profile: heavy heads are replicated, replicas
  split rows, per-shard live load flattens, and the same budget sustains
  more concurrent requests.

Both arms run the identical Poisson trace on identical weights; the
recorded signal is **tokens per scheduler step** (concurrency the budget
sustains) plus the analytic device-time gain (max-shard load ratio on the
measured profile, the fig3-style Eq. 4/5 number) across 2/4/8 shards.

``REPRO_BENCH_SMOKE=1`` trims the shard sweep for CI.
Returns a metrics dict (recorded by ``run.py`` — ``BENCH.json`` by
default; the PR-4-era committed copy lives in ``BENCH_pr4.json``).
"""
from __future__ import annotations

import math
import os
import time

import numpy as np

from repro.api import (
    CompressionConfig,
    Engine,
    EngineConfig,
    PlannerConfig,
    SchedulerConfig,
    get_smoke_config,
    init_params,
    synthesize_requests,
)

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")

SHARDS = [2, 4] if SMOKE else [2, 4, 8]
ROWS = 8
GEN = 6
PROMPT = (20, 28)
N_REQUESTS = 20
RATE = 2.0  # arrivals/step: admission-limited, not arrival-limited
BUDGET = 12  # compression budget (tokens/head)
HEAD_COLD = 0.1  # importance of the cold heads (hot heads get 1.0)
HEADROOM = 1.40  # per-shard budget over Fair-Copying's balanced need


def _model():
    """8-kv-head dense smoke model: placement-granular at 8 shards."""
    import jax.numpy as jnp  # noqa: F401  (jax import order)
    base = get_smoke_config("minitron-8b")
    return base.with_overrides(name="minitron-8b-smoke-8h", n_heads=8,
                               n_kv_heads=8, head_dim=8)


def _head_importance(model) -> np.ndarray:
    """(L, H) hot/cold split: every even head is memory-hot.

    Retrieval-style hot heads land wherever the architecture put them; a
    placement-blind layout has no defense.  SHA spreads head k to shard
    k mod n, so hot-at-even-indices keeps hot heads co-located on the
    same shards at every power-of-two shard count — the worst realistic
    case for plain TP, and exactly the layout-blindness FairKV fixes.
    """
    H = model.n_kv_heads
    imp = np.where(np.arange(H) % 2 == 0, 1.0, HEAD_COLD)
    return np.tile(imp, (model.n_layers, 1))


def _config(model, n_shards: int, planner: PlannerConfig,
            budget_per_shard: int) -> EngineConfig:
    return EngineConfig(
        model=model, n_shards=n_shards,
        max_seq_len=PROMPT[1] + GEN + 8,
        compression=CompressionConfig(policy="headkv", budget=BUDGET,
                                      alpha_max=2.0, obs_window=4, sink=2,
                                      decode_margin=GEN),
        planner=planner,
        scheduler=SchedulerConfig(
            max_rows=ROWS, enable_replan=False,
            max_live_tokens_per_shard=budget_per_shard))


def _arm_planner(arm: str, n_shards: int, n_heads: int) -> PlannerConfig:
    # identical slot grid for both arms: the spare slot is free capacity —
    # plain TP leaves it empty (no replicas), Fair-Copying fills it
    slots = math.ceil(n_heads / n_shards) + 1
    if arm == "tp":
        return PlannerConfig(mode="sha", fill_empty_slots=False,
                             slots_per_shard=slots)
    return PlannerConfig(mode="fairkv_dp", extra_copies=2 * n_shards,
                         slots_per_shard=slots, batch_cap=ROWS)


def run_shards(model, params, profile, head_imp, n_shards: int) -> dict:
    # per-shard budget: enough for Fair-Copying to keep ~ROWS rows live
    # when the load is balanced; the plain-TP hot shard needs ~E⁻¹× more
    per_row = float(profile.sum())  # mean Σ lengths one row pins
    budget_per_shard = int(HEADROOM * ROWS * per_row / n_shards)
    out = {"n_shards": n_shards, "budget_per_shard": budget_per_shard}
    for arm in ("tp", "fairkv"):
        cfg = _config(model, n_shards,
                      _arm_planner(arm, n_shards, model.n_kv_heads),
                      budget_per_shard)
        eng = Engine.build(cfg, params=params, profile=profile,
                           head_importance=head_imp)
        eng.warmup()
        reqs = synthesize_requests(N_REQUESTS, RATE, model.vocab_size,
                                   min_prompt=PROMPT[0], max_prompt=PROMPT[1],
                                   max_new_tokens=GEN, seed=11)
        t0 = time.time()
        trace = eng.run_trace(reqs, max_steps=4000)
        wall = time.time() - t0
        assert trace["finished"] == trace["total"], trace
        tps = trace["generated_tokens"] / trace["steps"]
        load = eng.plan.per_shard_load(profile)
        out[arm] = {
            "tokens_per_step": tps,
            "steps": trace["steps"],
            "wall_s": wall,
            "efficiency_E": float(eng.plan.efficiency(profile)),
            "makespan": float(load.max()),
            "replication_overhead": eng.plan.replication_overhead(),
            # from the consolidated stats snapshot (the scheduler's own
            # counters), not a re-tally of replan_log — replans are off
            # here, so both outcomes reading 0 is itself part of the check
            "replans": eng.stats().scheduler.replans_accepted,
            "replans_rejected": eng.stats().scheduler.replans_rejected,
        }
        assert out[arm]["replans"] == trace["replans"], trace
    out["tokens_per_step_gain"] = (out["fairkv"]["tokens_per_step"]
                                   / out["tp"]["tokens_per_step"])
    # fig3-style device-time gain on the same profile: throughput ∝ 1/makespan
    out["device_time_gain"] = (out["tp"]["makespan"]
                               / out["fairkv"]["makespan"])
    return out


def main():
    import jax
    import jax.numpy as jnp

    model = _model()
    params = init_params(model, jax.random.PRNGKey(0), dtype=jnp.float32,
                         max_seq_len=PROMPT[1] + GEN + 8)
    head_imp = _head_importance(model)
    # measured (L, H) profile (paper §4.1): both arms plan from the same
    # realized per-head workload, so the comparison is placement-only
    probe = Engine.build(_config(model, 2, _arm_planner("tp", 2, 8), 10**9),
                         params=params, head_importance=head_imp)
    rng = np.random.default_rng(5)
    profile = probe.measure_profile(
        rng.integers(0, model.vocab_size, (ROWS, PROMPT[1])))
    metrics = {"rows": ROWS, "requests": N_REQUESTS,
               "profile_imbalance": float(profile.max() / profile.mean()),
               "shards": []}
    worst = float("inf")
    for n in SHARDS:
        r = run_shards(model, params, profile, head_imp, n)
        metrics["shards"].append(r)
        worst = min(worst, r["tokens_per_step_gain"])
        print(f"fig8/tp{n},{r['fairkv']['wall_s'] * 1e6:.0f},"
              f"tp_tokens_per_step={r['tp']['tokens_per_step']:.3f};"
              f"fairkv_tokens_per_step={r['fairkv']['tokens_per_step']:.3f};"
              f"gain={r['tokens_per_step_gain']:.3f};"
              f"device_time_gain={r['device_time_gain']:.3f};"
              f"E_tp={r['tp']['efficiency_E']:.3f};"
              f"E_fairkv={r['fairkv']['efficiency_E']:.3f}")
    metrics["min_tokens_per_step_gain"] = worst
    print(f"fig8/min_gain,0,tokens_per_step_gain={worst:.3f}")
    assert worst > 1.0, (
        f"Fair-Copying must beat plain TP tokens/step, got {worst:.3f}")
    return metrics


if __name__ == "__main__":
    main()
