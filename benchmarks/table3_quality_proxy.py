"""Table 3 proxy: compression-quality ordering across policies.

LongBench + trained weights are unavailable offline, so we measure the
*attention-output fidelity* of each policy at matched budgets: cosine
similarity between compressed-cache decode logits and full-cache logits on a
smoke model with structured (repetition-heavy) synthetic prompts, generated
teacher-forced through `repro.api.Engine.generate`.  The paper's ordering
claim under test: Ada-SnapKV ≥ SnapKV ≈ Pyramid > StreamingLLM at every
budget.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (
    CompressionConfig,
    Engine,
    EngineConfig,
    PlannerConfig,
    init_params,
    list_policies,
)

POLICIES = tuple(list_policies())


def _logits_for(base_cfg, params, batch, teacher, ccfg, steps=4):
    eng = Engine.build(base_cfg.replace(compression=ccfg), params=params)
    res = eng.generate(batch, steps, teacher_tokens=teacher)
    return jnp.asarray(res.logits)


def run(budgets=(16, 32, 64), T: int = 96, B: int = 2, arch="minitron-8b"):
    base_cfg = EngineConfig.smoke(
        arch, n_shards=4, max_seq_len=160,
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=4),
        compression=CompressionConfig(policy="none", budget=T, capacity=T,
                                      obs_window=8, sink=2, decode_margin=8))
    cfg = base_cfg.model
    # one weight set for every arm (plan/slotify happen per-arm in build)
    params = init_params(cfg, jax.random.PRNGKey(base_cfg.seed),
                         dtype=jnp.float32, max_seq_len=base_cfg.max_seq_len)
    rng = np.random.default_rng(0)
    # repetition-heavy prompt: induces peaked attention → compressible
    base = rng.integers(0, cfg.vocab_size, (B, 16))
    tokens = np.concatenate([base[:, rng.integers(0, 16, 16)]
                             for _ in range((T + 16) // 16 + 1)], axis=1)
    tokens = jnp.asarray(tokens[:, :T + 8], jnp.int32)
    batch = {"tokens": tokens[:, :T]}
    teacher = np.asarray(tokens[:, T:T + 4])  # forced decode inputs
    full = _logits_for(base_cfg, params, batch, teacher,
                       base_cfg.compression)
    rows = []
    for budget in budgets:
        for policy in POLICIES:
            ccfg = CompressionConfig(policy=policy, budget=budget,
                                     alpha_max=2.0, obs_window=8, sink=2,
                                     decode_margin=8)
            lg = _logits_for(base_cfg, params, batch, teacher, ccfg)
            cos = float((full * lg).sum()
                        / (jnp.linalg.norm(full) * jnp.linalg.norm(lg)))
            rows.append({"name": f"table3/{policy}/budget{budget}",
                         "fidelity": cos})
    return rows


def main():
    for r in run():
        print(f"{r['name']},0,fidelity={r['fidelity']:.4f}")


if __name__ == "__main__":
    main()
