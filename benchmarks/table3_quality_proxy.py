"""Table 3 proxy: compression-quality ordering across policies.

LongBench + trained weights are unavailable offline, so we measure the
*attention-output fidelity* of each policy at matched budgets: cosine
similarity between compressed-cache decode logits and full-cache logits on a
smoke model with structured (repetition-heavy) synthetic prompts.  The
paper's ordering claim under test: Ada-SnapKV ≥ SnapKV ≈ Pyramid >
StreamingLLM at every budget.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.slot_cache import PlanArrays
from repro.compression.base import CompressionConfig
from repro.configs import get_smoke_config
from repro.core import PlannerConfig, build_plan, synthetic_profile
from repro.models import init_params
from repro.serving import decode_step, prefill, slotify_params

POLICIES = ("streaming_llm", "snapkv", "pyramidkv", "h2o", "ada_snapkv",
            "headkv")


def _logits_for(cfg, params, batch, tokens, ccfg, steps=4):
    prof = synthetic_profile(cfg.n_layers, cfg.n_kv_heads, budget=64,
                             skew=1.0, seed=1)
    plan = build_plan(prof, 4, PlannerConfig(mode="fairkv_dp", extra_copies=4))
    pa = PlanArrays.from_plan(plan)
    sp = slotify_params(params, plan, cfg)
    state, lg, _ = prefill(sp, batch, cfg, pa, ccfg)
    out = [lg]
    T = batch["tokens"].shape[1]
    for t in range(steps):
        state, lg = decode_step(sp, state, cfg, pa, ccfg,
                                tokens=tokens[:, T + t])
        out.append(lg)
    return jnp.stack(out, 1)


def run(budgets=(16, 32, 64), T: int = 96, B: int = 2, arch="minitron-8b"):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32,
                         max_seq_len=160)
    rng = np.random.default_rng(0)
    # repetition-heavy prompt: induces peaked attention → compressible
    base = rng.integers(0, cfg.vocab_size, (B, 16))
    tokens = np.concatenate([base[:, rng.integers(0, 16, 16)]
                             for _ in range((T + 16) // 16 + 1)], axis=1)
    tokens = jnp.asarray(tokens[:, :T + 8], jnp.int32)
    batch = {"tokens": tokens[:, :T]}
    full = _logits_for(cfg, params, batch, tokens, CompressionConfig(
        policy="none", budget=T, capacity=T, obs_window=8, sink=2,
        decode_margin=8))
    rows = []
    for budget in budgets:
        for policy in POLICIES:
            ccfg = CompressionConfig(policy=policy, budget=budget,
                                     alpha_max=2.0, obs_window=8, sink=2,
                                     decode_margin=8)
            lg = _logits_for(cfg, params, batch, tokens, ccfg)
            cos = float((full * lg).sum()
                        / (jnp.linalg.norm(full) * jnp.linalg.norm(lg)))
            rows.append({"name": f"table3/{policy}/budget{budget}",
                         "fidelity": cos})
    return rows


def main():
    for r in run():
        print(f"{r['name']},0,fidelity={r['fidelity']:.4f}")


if __name__ == "__main__":
    main()
