"""Figure 6 (repo extension): continuous-batching throughput under load.

Drives the real continuous-batching path through `repro.api.Engine`
(`run_trace`: admission, interleaved decode, retirement) over an identical
Poisson request trace for the ``sha`` and ``fairkv_dp`` planners on a smoke
model, and reports end-to-end tokens/s plus p50/p99 request latency (in
scheduler steps and wall seconds).

This measures the *system* path the paper's 1.66× claim lives on: sustained
multi-request load against the slot cache, not a single fixed batch.  On CPU
the absolute tok/s is compile-dominated; the latency-step percentiles and the
sha-vs-fairkv comparison are the meaningful outputs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.api import (
    CompressionConfig,
    Engine,
    EngineConfig,
    PlannerConfig,
    SchedulerConfig,
    init_params,
    latency_percentiles,
    synthesize_requests,
)

ARCH = "minitron-8b"
N_REQUESTS = 8
RATE = 0.5  # arrivals per decode step
ROWS = 2
GEN = 8
SHARDS = 4
BUDGET = 16


def run_one(planner: str, base_cfg: EngineConfig, params: dict) -> dict:
    cfg = base_cfg.replace(planner=PlannerConfig(
        mode=planner, extra_copies=4, batch_cap=ROWS))
    eng = Engine.build(cfg, params=params)
    # compile the decode step outside the timed region (an all-inactive step
    # has the same trace signature as live ones and is a no-op on state)
    eng.warmup()
    # fresh Request objects per arm: the scheduler mutates them in place
    reqs = synthesize_requests(N_REQUESTS, RATE, cfg.model.vocab_size,
                               min_prompt=12, max_prompt=24,
                               max_new_tokens=GEN, seed=0)
    t0 = time.time()
    out = eng.run_trace(reqs, max_steps=2000)
    out["wall_s"] = time.time() - t0
    out["pct"] = latency_percentiles(eng.finished_requests)
    st = eng.stats()  # consolidated typed snapshot (DESIGN.md §8)
    out["imbalance"] = st.scheduler.imbalance
    # replan counts come from the obs registry — the same counter the
    # scheduler increments — not a re-tally of replan_log
    out["replans_accepted"] = st.scheduler.replans_accepted
    out["replans_rejected"] = st.scheduler.replans_rejected
    assert out["finished"] == out["total"], out
    assert out["replans_accepted"] == out["replans"], out
    return out


def main():
    base_cfg = EngineConfig.smoke(
        ARCH, n_shards=SHARDS, max_seq_len=24 + GEN + 8,
        compression=CompressionConfig(policy="ada_snapkv", budget=BUDGET,
                                      alpha_max=2.0, obs_window=8, sink=2,
                                      decode_margin=8),
        scheduler=SchedulerConfig(max_rows=ROWS, enable_replan=False))
    # one weight set for every arm (plan/slotify happen per-arm in build)
    params = init_params(base_cfg.model, jax.random.PRNGKey(base_cfg.seed),
                         dtype=jnp.float32, max_seq_len=base_cfg.max_seq_len)
    # warmup: populate the op-dispatch/compile caches so neither timed arm
    # pays the one-time tracing cost (CPU runs are otherwise compile-bound)
    run_one("sha", base_cfg, params)
    results = {}
    for planner in ("sha", "fairkv_dp"):
        r = run_one(planner, base_cfg, params)
        results[planner] = r
        pct = r["pct"]
        print(f"fig6/{ARCH}/{planner},{r['wall_s'] * 1e6:.0f},"
              f"tokens_per_s={r['generated_tokens'] / r['wall_s']:.2f};"
              f"p50_steps={pct['p50_steps']:.0f};"
              f"p99_steps={pct['p99_steps']:.0f};"
              f"p50_s={pct['p50_s']:.3f};p99_s={pct['p99_s']:.3f};"
              f"p50_ttft_s={pct['p50_ttft_s']:.3f};"
              f"p99_ttft_s={pct['p99_ttft_s']:.3f};"
              f"p50_itl_s={pct['p50_itl_s']:.3f};"
              f"p99_itl_s={pct['p99_itl_s']:.3f};"
              f"steps={r['steps']};"
              f"mid_stream_admissions={r['mid_stream_admissions']};"
              f"replans={r['replans_accepted']:.0f}")
    gain = (results["fairkv_dp"]["generated_tokens"]
            / results["fairkv_dp"]["wall_s"]) / (
        results["sha"]["generated_tokens"] / results["sha"]["wall_s"])
    print(f"fig6/gain_dp_over_sha,0,gain={gain:.3f}")
    return {  # machine-readable summary for BENCH_pr3.json
        planner: {
            "tokens_per_s": r["generated_tokens"] / r["wall_s"],
            "p50_steps": r["pct"]["p50_steps"],
            "p99_steps": r["pct"]["p99_steps"],
            "p50_ttft_s": r["pct"]["p50_ttft_s"],
            "p50_itl_s": r["pct"]["p50_itl_s"],
            "steps": r["steps"],
            "replans": r["replans_accepted"],
        } for planner, r in results.items()
    } | {"gain_dp_over_sha": gain}


if __name__ == "__main__":
    main()
