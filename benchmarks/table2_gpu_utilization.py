"""Table 2: utilization of Ada-SnapKV under plain tensor parallelism (SHA).

Paper: GPU utilization drops as TP grows (92% @TP2 → 57-75% @TP8) and as the
budget grows.  We reproduce with realized Ada-SnapKV lengths + the SHA plan,
E = mean/max shard time (Eq. 5) over the attention-decode component plus the
v5e-derived uniform overhead for the dense part.
"""
from __future__ import annotations


from benchmarks.common import (
    DecodeTimeModel,
    SIM_MODELS,
    make_plans,
    realized_lengths,
    v5e_overhead_tokens,
)


def run(budgets=(128, 256, 512, 1024), tps=(2, 4, 8), batch: int = 32,
        layers_cap: int = 8) -> list:
    rows = []
    for model_name, dims in SIM_MODELS.items():
        L = min(dims["n_layers"], layers_cap)  # per-layer i.i.d.: cap for speed
        scale = dims["n_layers"] / L
        params_bytes = 2.0 * (dims["d_model"] * dims["d_ff"] * 3
                              + dims["d_model"] * dims["d_model"] * 2
                              ) * dims["n_layers"]
        for budget in budgets:
            lengths = realized_lengths(L, dims["n_heads"], budget, batch,
                                       head_skew=1.0, head_seed=7)
            for tp in tps:
                plans = make_plans(lengths, tp)
                ovh = v5e_overhead_tokens(
                    dims["d_model"], dims["d_ff"], dims["n_layers"], batch,
                    tp, dims["head_dim"], params_bytes / tp) / scale
                tm = DecodeTimeModel(overhead_tokens=ovh)
                util = tm.utilization(plans["sha"], lengths)
                rows.append({
                    "name": f"table2/{model_name}/budget{budget}/tp{tp}",
                    "utilization": util,
                })
    return rows


def main():
    for r in run():
        print(f"{r['name']},0,utilization={r['utilization']:.3f}")


if __name__ == "__main__":
    main()
