"""Figure 11 (repo extension): shared-prefix block reuse + chunked prefill.

Two arms, both on the paged backend (DESIGN.md §14):

**Arm A — effective capacity.**  A burst of requests sharing an 80%-long
prompt prefix hits a deliberately small block pool.  Without sharing, each
request charges its full prompt against the pool, so only a couple fit
concurrently; with the prefix index, every hit charges only its unshared
blocks (the shared ones are refcounted, stored once) and the same pool
holds several times more concurrent requests.  The observable is peak
concurrency (active + chunk-prefilling rows) over the trace — the
"effective capacity" of the pool — plus the bytes the pool never had to
hold twice.

**Arm B — chunked prefill vs head-of-line blocking.**  A long "aggressor"
prompt arrives while a cohort of short interactive requests streams in.
Monolithic prefill runs the whole aggressor prompt inside one scheduler
tick, stalling every concurrent decode; chunked prefill (fixed-width
chunks interleaved with decode ticks) bounds the per-tick prefill work, so
the short cohort's wall-clock TTFT — p99 especially — drops.  Both arms
run the same trace on warmed engines (compile cost paid before the
measured window).

Acceptance (``REPRO_BENCH_SMOKE=0``): ``capacity_gain >= 2.0`` (Arm A) and
``p99 TTFT chunked < monolithic`` for the short cohort (Arm B); the
committed run in ``BENCH_pr8.json`` records the realized margins.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.api import (
    CompressionConfig,
    Engine,
    EngineConfig,
    PagingConfig,
    PlannerConfig,
    PrefixConfig,
    SchedulerConfig,
    latency_percentiles,
)
from repro.serving.request import Request

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")

ARCH = "minitron-8b"
BS = 16  # KV block size
SEED = 13

# --- Arm A: effective capacity under an 80%-shared burst --------------------
CAP_ROWS = 8
CAP_SHARED_LEN = 48  # template prefix (3 full chunks)
CAP_PROMPT = 64      # total prompt: 48 shared + 16 unique suffix
CAP_GEN = 8
CAP_N = 6 if SMOKE else 12
CAP_SHARED_FRAC = 0.8  # a fifth of the burst stays fully private

# --- Arm B: chunked prefill vs head-of-line blocking ------------------------
HOL_CHUNK = 32
HOL_AGGRESSOR = 128 if SMOKE else 256  # long-prompt tick-staller
HOL_SHORT = 16
HOL_SHORT_N = 6 if SMOKE else 12
HOL_GEN = 6
# one admission wave: every short shares the aggressor's prefill tick, so
# p99 TTFT measures head-of-line blocking rather than row-queue wait
HOL_ROWS = HOL_SHORT_N + 1


def _cfg(*, enabled: bool, chunk: int, n_blocks: int, rows: int,
         max_seq: int, budget: int = 128) -> EngineConfig:
    return EngineConfig.smoke(
        ARCH, max_seq_len=max_seq,
        compression=CompressionConfig(policy="none", budget=budget,
                                      capacity=budget, decode_margin=16,
                                      obs_window=8),
        planner=PlannerConfig(batch_cap=rows),
        scheduler=SchedulerConfig(max_rows=rows, enable_replan=False),
        cache_backend="paged",
        paging=PagingConfig(block_size=BS, n_blocks=n_blocks),
        prefix=PrefixConfig(enabled=enabled, chunk_tokens=chunk))


# ---------------------------------------------------------------------------
# Arm A
# ---------------------------------------------------------------------------


def capacity_trace(vocab: int):
    """One early donor + a step-8 burst, CAP_SHARED_FRAC of it sharing the
    donor's 48-token prefix (the donor registers the prefix at its chunk
    boundaries before the burst lands)."""
    rng = np.random.default_rng(SEED)
    shared = rng.integers(1, vocab, size=CAP_SHARED_LEN).astype(np.int32)
    n_shared = max(1, int(round(CAP_SHARED_FRAC * CAP_N)))
    reqs = []
    for i in range(CAP_N):
        if i < n_shared:
            sfx = rng.integers(1, vocab, size=CAP_PROMPT - CAP_SHARED_LEN)
            prompt = np.concatenate([shared, sfx.astype(np.int32)])
        else:
            prompt = rng.integers(1, vocab, size=CAP_PROMPT).astype(np.int32)
        reqs.append(Request(req_id=i, prompt=prompt,
                            arrival_step=0 if i == 0 else 8,
                            max_new_tokens=CAP_GEN))
    return reqs


def run_capacity(enabled: bool) -> dict:
    """Peak concurrency of the burst against a pool sized for ~2 private
    requests (admission needs prompt·H/bs + 2H blocks per layer)."""
    probe = _cfg(enabled=False, chunk=0, n_blocks=64, rows=CAP_ROWS,
                 max_seq=CAP_PROMPT + CAP_GEN + 8)
    H = probe.model.n_kv_heads
    private_need = CAP_PROMPT * H // BS + 2 * H
    n_blocks = int(2.3 * private_need) + 1  # ~2 private requests + null
    eng = Engine.build(_cfg(enabled=enabled, chunk=BS, n_blocks=n_blocks,
                            rows=CAP_ROWS, max_seq=CAP_PROMPT + CAP_GEN + 8))
    sched = eng._ensure_scheduler()
    peak, steps = 0, 0
    for _ in eng.stream(capacity_trace(eng.cfg.model.vocab_size),
                        max_steps=2000):
        peak = max(peak, len(sched.active) + len(sched.prefilling))
        steps = sched.step_idx
    assert all(r.is_finished for r in sched.finished), "trace did not drain"
    sched.backend.pool.check_invariants()
    pst = eng.stats().prefix  # consolidated typed snapshot (DESIGN.md §8)
    snap = eng.metrics()
    saved = 0
    if "prefix_bytes_saved" in snap:  # peak gauge over the run is not kept;
        saved = snap["prefix_bytes_saved"]["series"][0]["value"]
    return {
        "peak_concurrent": peak, "steps": steps, "n_blocks": n_blocks,
        "pool_blocks_per_layer": n_blocks, "hits": pst.hits or 0,
        "misses": pst.misses or 0,
        "final_bytes_saved": saved,
        "preemptions": sum(r.n_preemptions for r in sched.finished),
    }


# ---------------------------------------------------------------------------
# Arm B
# ---------------------------------------------------------------------------


def hol_trace(vocab: int, warm: bool = False, base: int = 0):
    """A long aggressor and a short interactive cohort arriving in the
    same burst.  Arrivals are step-indexed, so a later-arriving request
    never waits on an earlier slow tick — the cohort must land in the
    aggressor's admission step to pay (or dodge) its prefill wall time.
    ``base`` offsets arrivals past the warmup trace on a reused engine
    (the scheduler's step counter is monotonic across traces)."""
    rng = np.random.default_rng(SEED + (1 if warm else 2))
    id0 = 100 if not warm else 0
    reqs = [Request(req_id=id0,
                    prompt=rng.integers(1, vocab, size=HOL_AGGRESSOR)
                    .astype(np.int32),
                    arrival_step=base, max_new_tokens=HOL_GEN)]
    n = 2 if warm else HOL_SHORT_N
    for i in range(n):
        reqs.append(Request(
            req_id=id0 + i + 1,
            prompt=rng.integers(1, vocab, size=HOL_SHORT).astype(np.int32),
            arrival_step=base, max_new_tokens=HOL_GEN))
    return reqs


def run_hol(chunk: int) -> dict:
    """One warmed engine per mode; percentiles over the measured cohort.

    Driven through ``Engine.stream`` (not ``run_trace``): completion is
    judged on the measured requests alone, so the warmup trace's finished
    entries can't truncate the measured window.
    """
    eng = Engine.build(_cfg(enabled=False, chunk=chunk, n_blocks=0,
                            rows=HOL_ROWS, budget=HOL_AGGRESSOR,
                            max_seq=HOL_AGGRESSOR + HOL_GEN + 8))
    vocab = eng.cfg.model.vocab_size
    eng.run_trace(hol_trace(vocab, warm=True), max_steps=2000)  # compile
    base = eng._ensure_scheduler().step_idx
    reqs = hol_trace(vocab, base=base)
    t0 = time.time()
    for _ in eng.stream(reqs, max_steps=base + 2000):
        pass
    wall = time.time() - t0
    shorts = [r for r in reqs if r.req_id > 100]
    assert all(r.is_finished for r in reqs), "trace did not drain"
    pct = latency_percentiles(shorts)
    return {
        "wall_s": wall,
        "p50_ttft_s": pct.get("p50_ttft_s"),
        "p99_ttft_s": pct.get("p99_ttft_s"),
        "p99_ttft_steps": pct.get("p99_ttft_steps"),
        "aggressor_ttft_s": next(r for r in reqs
                                 if r.req_id == 100).ttft_seconds(),
    }


# ---------------------------------------------------------------------------


def main():
    metrics = {
        "conditions": {
            "smoke": SMOKE, "arch": ARCH, "block_size": BS, "seed": SEED,
            "capacity": {"rows": CAP_ROWS, "prompt": CAP_PROMPT,
                         "shared_len": CAP_SHARED_LEN, "n": CAP_N,
                         "shared_fraction": CAP_SHARED_FRAC,
                         "gen": CAP_GEN},
            "hol": {"rows": HOL_ROWS, "chunk": HOL_CHUNK,
                    "aggressor": HOL_AGGRESSOR, "short": HOL_SHORT,
                    "short_n": HOL_SHORT_N, "gen": HOL_GEN},
        },
    }

    # Arm A
    arm_a = {}
    for name, enabled in (("no_sharing", False), ("sharing", True)):
        t0 = time.time()
        arm_a[name] = run_capacity(enabled)
        print(f"fig11/capacity_{name},{(time.time() - t0) * 1e6:.0f},"
              f"peak={arm_a[name]['peak_concurrent']};"
              f"steps={arm_a[name]['steps']};"
              f"hits={arm_a[name]['hits']}")
    gain = (arm_a["sharing"]["peak_concurrent"]
            / max(arm_a["no_sharing"]["peak_concurrent"], 1))
    metrics["capacity"] = arm_a
    metrics["capacity_gain"] = gain
    print(f"fig11/capacity_gain,0,sharing_over_private={gain:.2f};"
          f"bytes_saved={arm_a['sharing']['final_bytes_saved']}")
    assert arm_a["sharing"]["hits"] >= 1, "sharing arm never hit the index"

    # Arm B
    arm_b = {}
    for name, chunk in (("monolithic", 0), ("chunked", HOL_CHUNK)):
        t0 = time.time()
        arm_b[name] = run_hol(chunk)
        print(f"fig11/hol_{name},{(time.time() - t0) * 1e6:.0f},"
              f"p99_ttft_ms={arm_b[name]['p99_ttft_s'] * 1e3:.1f};"
              f"p50_ttft_ms={arm_b[name]['p50_ttft_s'] * 1e3:.1f}")
    ttft_ratio = (arm_b["monolithic"]["p99_ttft_s"]
                  / max(arm_b["chunked"]["p99_ttft_s"], 1e-9))
    metrics["hol"] = arm_b
    metrics["hol_p99_ttft_ratio"] = ttft_ratio
    print(f"fig11/hol_p99_ttft,0,mono_over_chunked={ttft_ratio:.2f}")

    if not SMOKE:
        assert gain >= 2.0, (
            f"sharing must >= 2x effective capacity, got {gain:.2f}x "
            f"(peaks {arm_a['sharing']['peak_concurrent']} vs "
            f"{arm_a['no_sharing']['peak_concurrent']})")
        assert ttft_ratio > 1.0, (
            f"chunked prefill must lower short-cohort p99 TTFT, got "
            f"mono/chunked = {ttft_ratio:.3f}")
    return metrics


if __name__ == "__main__":
    main()
