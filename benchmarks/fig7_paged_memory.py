"""Figure 7 (repo extension): paged vs slot cache memory at equal HBM.

The slot cache pads every (slot, row) to the static capacity ``C``; the
paged backend (DESIGN.md §9) allocates fixed-size blocks proportional to
each (slot, row)'s *realized* retained length.  The waste the paged backend
recovers is largest exactly when compression is most imbalanced — the
Ada-SnapKV regime FairKV targets — and it converts directly into batch
capacity and throughput.

Two measurements:

1. **Analytic max sustainable batch** — run the real Ada-SnapKV selection
   (`benchmarks.common.realized_lengths`) across compression ratios, place
   heads with the fairkv_dp planner, and count how many request rows fit in
   the HBM the slot cache spends on a reference batch.  The per-row paged
   cost honors block rounding and the one-block-per-owned-head floor, so
   the gain is what the allocator would actually realize.

2. **System throughput** — drive the real continuous-batching engine (slot
   vs paged at an equal cache-byte budget, paged getting the freed bytes
   back as extra decode rows) over one Poisson trace and report end-to-end
   tokens/s and preemptions.  CPU wall times are compile-dominated; the
   comparison and the admission/preemption telemetry are the signal.

``REPRO_BENCH_SMOKE=1`` shrinks the sweep for CI.

Returns a metrics dict (recorded by ``run.py`` — ``BENCH.json`` by
default; the PR-3-era committed copy lives in ``BENCH_pr3.json``).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import realized_lengths
from repro.api import (
    CompressionConfig,
    Engine,
    EngineConfig,
    PagingConfig,
    PlannerConfig,
    SchedulerConfig,
    build_plan,
    init_params,
    profile_from_lengths,
    synthesize_requests,
)
from repro.core.efficiency import owned_mask
from repro.paging.block_pool import blocks_for_tokens

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")

# analytic sweep: paper-ish dims, trimmed under smoke
N_LAYERS = 4 if SMOKE else 8
N_HEADS = 8
N_SHARDS = 4
T = 2048 if SMOKE else 8192
BATCH = 8  # reference rows the slot cache budget is sized for
BLOCK_SIZE = 16
RATIOS = [0.02, 0.08] if SMOKE else [0.01, 0.02, 0.05, 0.10, 0.20]
HEAD_SKEW = 1.0  # Ada-SnapKV-style imbalanced profile

# system run: smoke engine, identical trace for both arms
ARCH = "minitron-8b"
SYS_ROWS_SLOT = 2
SYS_GEN = 6
SYS_REQUESTS = 6
SYS_BUDGET = 12


def paged_row_blocks(lengths_lhb: np.ndarray, plan, block_size: int
                     ) -> np.ndarray:
    """(B,) blocks each row pins under ``plan`` ownership (incl. the
    one-block floor for every owned (layer, slot))."""
    L, H, B = lengths_lhb.shape
    out = np.zeros(B, np.int64)
    for li, lp in enumerate(plan.layers):
        for slot in range(len(lp.slot_head)):
            h = int(lp.slot_head[slot])
            if h < 0:
                continue
            msk = owned_mask(int(lp.replica_idx[slot]),
                             int(lp.replica_count[slot]), B)
            for b in np.nonzero(msk)[0]:
                out[b] += blocks_for_tokens(
                    max(int(lengths_lhb[li, h, b]), 1), block_size)
    return out


def analytic_max_batch(ratio: float) -> dict:
    """Max sustainable batch at equal HBM, slot vs paged, one ratio."""
    budget = max(8, int(round(ratio * T)))
    alpha_max = 4.0
    lengths = realized_lengths(N_LAYERS, N_HEADS, budget, BATCH, T=T,
                               head_skew=HEAD_SKEW, policy="ada_snapkv",
                               alpha_max=alpha_max)
    prof = profile_from_lengths(lengths)
    plan = build_plan(prof, N_SHARDS, PlannerConfig(
        mode="fairkv_dp", extra_copies=4, batch_cap=BATCH))
    S = plan.n_shards * plan.slots_per_shard
    cap = int(round(alpha_max * budget))
    cap_blocks = blocks_for_tokens(cap, BLOCK_SIZE)
    # equal HBM budget: the bytes the slot cache spends on BATCH rows,
    # in block units (C rounded up to whole blocks on both sides)
    hbm_blocks = N_LAYERS * S * BATCH * cap_blocks
    row_blocks = paged_row_blocks(lengths, plan, BLOCK_SIZE)
    mean_row = float(row_blocks.mean())
    paged_batch = int(hbm_blocks // mean_row)
    return {
        "budget": budget,
        "ratio": budget / T,
        "slot_batch": BATCH,
        "paged_batch": paged_batch,
        "gain": paged_batch / BATCH,
        "mean_row_blocks": mean_row,
        "slot_row_blocks": N_LAYERS * S * cap_blocks,
    }


def system_run(backend: str, rows: int, n_blocks: int, params=None):
    cfg = EngineConfig.smoke(
        ARCH, n_shards=4, max_seq_len=24 + SYS_GEN + 8,
        compression=CompressionConfig(policy="ada_snapkv", budget=SYS_BUDGET,
                                      alpha_max=2.0, obs_window=8, sink=2,
                                      decode_margin=8),
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=4,
                              batch_cap=rows),
        scheduler=SchedulerConfig(max_rows=rows, enable_replan=False),
        cache_backend=backend,
        paging=PagingConfig(block_size=8, n_blocks=n_blocks))
    eng = Engine.build(cfg, params=params)
    eng.warmup()
    reqs = synthesize_requests(SYS_REQUESTS, 0.6, cfg.model.vocab_size,
                               min_prompt=12, max_prompt=24,
                               max_new_tokens=SYS_GEN, seed=0)
    t0 = time.time()
    out = eng.run_trace(reqs, max_steps=2000)
    out["wall_s"] = time.time() - t0
    assert out["finished"] == out["total"], out
    return eng, out


def main():
    metrics = {"block_size": BLOCK_SIZE, "head_skew": HEAD_SKEW,
               "analytic": [], "system": {}}
    # --- analytic sweep ------------------------------------------------------
    for ratio in RATIOS:
        t0 = time.time()
        r = analytic_max_batch(ratio)
        metrics["analytic"].append(r)
        print(f"fig7/max_batch/ratio_{r['ratio']:.3f},"
              f"{(time.time() - t0) * 1e6:.0f},"
              f"slot_batch={r['slot_batch']};paged_batch={r['paged_batch']};"
              f"gain={r['gain']:.2f}")
    gains = [r["gain"] for r in metrics["analytic"]]
    metrics["min_gain"] = min(gains)
    metrics["max_gain"] = max(gains)
    print(f"fig7/max_batch_gain,0,min={min(gains):.2f};max={max(gains):.2f}")

    # --- system run: equal cache bytes, paged gets the bytes back as rows ----
    # slot arm cache bytes/layer: S * ROWS * C; paged pool sized to match
    # (n_blocks-1 usable blocks of BLOCK bs tokens), decode width doubled.
    base = EngineConfig.smoke(ARCH)
    params = init_params(base.model, jax.random.PRNGKey(base.seed),
                         dtype=jnp.float32, max_seq_len=24 + SYS_GEN + 8)
    ccfg = CompressionConfig(policy="ada_snapkv", budget=SYS_BUDGET,
                             alpha_max=2.0, obs_window=8, sink=2,
                             decode_margin=8)
    cap = ccfg.static_capacity()
    # untimed warmup arm (fig6 pattern): populate the op-dispatch/compile
    # caches so neither timed arm pays the one-time tracing cost
    system_run("slot", SYS_ROWS_SLOT, 0, params=params)
    eng_s, out_s = system_run("slot", SYS_ROWS_SLOT, 0, params=params)
    S = eng_s.plan.n_shards * eng_s.plan.slots_per_shard
    equal_blocks = S * SYS_ROWS_SLOT * blocks_for_tokens(cap, 8) + 1
    eng_p, out_p = system_run("paged", 2 * SYS_ROWS_SLOT, equal_blocks,
                              params=params)
    for name, out in (("slot", out_s), ("paged", out_p)):
        tps = out["generated_tokens"] / out["wall_s"]
        tpstep = out["generated_tokens"] / out["steps"]
        metrics["system"][name] = {
            "tokens_per_s": tps, "tokens_per_step": tpstep,
            "steps": out["steps"], "preemptions": out["preemptions"],
            "mid_stream_admissions": out["mid_stream_admissions"],
            "memory": out["memory"],
        }
        print(f"fig7/system/{name},{out['wall_s'] * 1e6:.0f},"
              f"tokens_per_s={tps:.2f};tokens_per_step={tpstep:.2f};"
              f"steps={out['steps']};preemptions={out['preemptions']}")
    # tokens/step is the hardware-agnostic signal: at equal cache bytes the
    # paged arm sustains more concurrent rows, finishing the trace in fewer
    # decode ticks.  (CPU *wall* tokens/s also reflects that CPU decode cost
    # grows with batch width — on the HBM-bound accelerator decode path,
    # per-step cost tracks Σ retained lengths, which is equal here.)
    step_gain = (metrics["system"]["paged"]["tokens_per_step"]
                 / metrics["system"]["slot"]["tokens_per_step"])
    tps_gain = (metrics["system"]["paged"]["tokens_per_s"]
                / metrics["system"]["slot"]["tokens_per_s"])
    metrics["system"]["tokens_per_step_gain"] = step_gain
    metrics["system"]["tokens_per_s_gain"] = tps_gain
    print(f"fig7/system/gain_paged_over_slot,0,"
          f"tokens_per_step_gain={step_gain:.3f};"
          f"wall_tokens_per_s_gain={tps_gain:.3f}")
    return metrics


if __name__ == "__main__":
    main()
