"""Figure 1: decode latency is linear in batch size B and KV budget C.

Measured on this host: the jitted slot-decode attention (jnp ref path) is
timed across a (batch × budget) grid; we fit t = a + b·B + c·C + d·B·C and
report R² plus the per-cross-section linear fits the paper plots.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.core import LinearLatencyModel
from repro.kernels.ref import fairkv_decode_ref


def _decode_latency(B: int, C: int, S: int = 8, G: int = 4, Dh: int = 64,
                    seed: int = 0) -> float:
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, G, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(S, B, C, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(S, B, C, Dh)), jnp.float32)
    lengths = jnp.full((S, B), C, jnp.int32)
    fn = jax.jit(lambda q, k, v, l: fairkv_decode_ref(q, k, v, l))
    us, _ = timed(fn, q, k, v, lengths)
    return us


def run(batches=(8, 16, 32, 64), budgets=(128, 256, 512, 1024)) -> dict:
    samples = []
    for B in batches:
        for C in budgets:
            us = _decode_latency(B, C)
            samples.append((float(B), float(C), us))
    model = LinearLatencyModel.fit(samples)
    r2 = model.r2(samples)
    # per-cross-section linear fits (the paper's two panels)
    slopes_b = {}
    for C in budgets:
        xs = np.array([s[0] for s in samples if s[1] == C])
        ys = np.array([s[2] for s in samples if s[1] == C])
        A = np.stack([xs, np.ones_like(xs)], 1)
        coef, res, *_ = np.linalg.lstsq(A, ys, rcond=None)
        ss_tot = ((ys - ys.mean()) ** 2).sum()
        slopes_b[C] = 1 - (res[0] / ss_tot if len(res) and ss_tot > 0 else 0.0)
    return {"samples": samples, "model": model, "r2": r2,
            "r2_vs_batch": slopes_b}


def main():
    out = run()
    m = out["model"]
    print(f"fig1/bilinear_fit,{np.mean([s[2] for s in out['samples']]):.1f},"
          f"r2={out['r2']:.4f};a={m.a:.2f};b={m.b:.3f};c={m.c:.4f};d={m.d:.5f}")
    for C, r2 in out["r2_vs_batch"].items():
        print(f"fig1/linear_in_B_at_budget{C},0,r2={r2:.4f}")


if __name__ == "__main__":
    main()
