"""Figure 4: ablation — SHA vs FairKV w/o fair-copying vs FairKV with it.

Paper: both FairKV arms beat the standard model; fair-copying adds a further
step.  Utilization per arm on the 70B-like model across budgets.
"""
from __future__ import annotations

from benchmarks.common import (
    DecodeTimeModel,
    SIM_MODELS,
    make_plans,
    realized_lengths,
    v5e_overhead_tokens,
)

MODEL = "llama70b-like(qwen1.5-110b)"


def run(budgets=(128, 256, 512, 1024), tp: int = 8, batch: int = 32,
        layers_cap: int = 8) -> list:
    dims = SIM_MODELS[MODEL]
    L = min(dims["n_layers"], layers_cap)
    scale = dims["n_layers"] / L
    params_bytes = 2.0 * (dims["d_model"] * dims["d_ff"] * 3
                          + dims["d_model"] * dims["d_model"] * 2
                          ) * dims["n_layers"]
    rows = []
    for budget in budgets:
        lengths = realized_lengths(L, dims["n_heads"], budget, batch,
                                   head_skew=1.0, head_seed=7)
        plans = make_plans(lengths, tp)
        ovh = v5e_overhead_tokens(dims["d_model"], dims["d_ff"],
                                  dims["n_layers"], batch, tp,
                                  dims["head_dim"], params_bytes / tp) / scale
        tm = DecodeTimeModel(overhead_tokens=ovh)
        utils = {k: tm.utilization(p, lengths) for k, p in plans.items()}
        rows.append({"name": f"fig4/budget{budget}/tp{tp}", **utils})
    return rows


def main():
    for r in run():
        print(f"{r['name']},0,sha={r['sha']:.3f};"
              f"nodp={r['fairkv_nodp']:.3f};dp={r['fairkv_dp']:.3f}")


if __name__ == "__main__":
    main()
