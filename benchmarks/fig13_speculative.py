"""Figure 13 (repo extension): self-speculative decoding throughput.

Three arms over one identical burst trace on the paged backend, one
warmed engine per arm (DESIGN.md §16):

- **single_token** — the baseline continuous scheduler, one greedy token
  per decode tick.
- **speculative** — the gated arm: a half-depth early-exit draft
  (``draft_layers = L // 2``) proposes ``max_k`` tokens per tick at
  ``d / L`` of the target's cost each, and one batched multi-query
  verify pass commits the accepted run.
- **full_depth_draft** — reported, not gated: ``draft_layers = 0`` makes
  the draft the target itself, isolating the dispatch-amortization part
  of the win (fewer scheduler ticks) from the cheap-draft part.

**The early-exit operating point.**  Self-speculative decoding pays off
when the truncated forward agrees with the full model often (LayerSkip
reports 70-90% on trained checkpoints).  This repo's smoke models have
random weights, where a truncated draft accepts ~10% — the system would
be benchmarked at an operating point no deployment runs at.  The suite
therefore synthesizes the high-agreement regime structurally: the top
``L - d`` layers' residual contributions are zeroed (``wo`` and ``w2``),
making the half-depth draft agree with the target *exactly* (acceptance
1.0) while propose still runs only ``d`` of ``L`` layers.  Every arm
shares these same weights, and tokens are asserted bit-identical across
arms — the speedup is never bought with different output.

Prompts are fixed-length so prefill compiles once in the warm trace;
the timed window is decode-bound, which is what speculation targets.

Acceptance (``REPRO_BENCH_SMOKE=0``): ``speedup >= 1.3`` at
``acceptance >= 0.7`` in the speculative arm (smoke gate: ``1.1``); the
committed run in ``BENCH_pr10.json`` records the realized margins.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (
    CompressionConfig,
    Engine,
    EngineConfig,
    PagingConfig,
    PlannerConfig,
    SchedulerConfig,
    SpeculationConfig,
    init_params,
    latency_percentiles,
    synthesize_requests,
)

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")

ARCH = "minitron-8b"
SEED = 17
ROWS = 4
# the trace is identical in smoke and full mode (it is already small);
# only the gate differs — wall-clock ratios need the decode-bound window
N_REQUESTS = 8
PROMPT = 12  # fixed length: one prefill compile, decode-bound timed window
GEN = 48
MAX_K = 7
CAP = PROMPT + GEN + 8
GATE_SPEEDUP = 1.1 if SMOKE else 1.3
GATE_ACCEPTANCE = 0.7


def _cfg(spec: SpeculationConfig | None = None) -> EngineConfig:
    return EngineConfig.smoke(
        ARCH, n_shards=4, max_seq_len=CAP,
        compression=CompressionConfig(policy="none", budget=CAP,
                                      capacity=CAP, obs_window=8, sink=2,
                                      decode_margin=8),
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=6,
                              batch_cap=ROWS),
        scheduler=SchedulerConfig(max_rows=ROWS, enable_replan=False),
        cache_backend="paged", paging=PagingConfig(block_size=8),
        speculation=spec or SpeculationConfig())


def early_exit_params(cfg: EngineConfig, draft_layers: int) -> dict:
    """Init params, then zero the residual contributions (attention
    o-projection + MLP down-projection) of every layer >= draft_layers:
    the truncated forward equals the full forward by construction, so the
    draft's acceptance is exactly 1.0 at ``d / L`` propose cost."""
    params = init_params(cfg.model, jax.random.PRNGKey(cfg.seed),
                         dtype=jnp.float32, max_seq_len=cfg.max_seq_len)
    for i in range(draft_layers, cfg.model.n_layers):
        pl = dict(params["layers"][i])
        pl["wo"] = jnp.zeros_like(pl["wo"])
        pl["w2"] = jnp.zeros_like(pl["w2"])
        params["layers"][i] = pl
    return params


def _reqs(vocab: int, seed: int):
    return synthesize_requests(N_REQUESTS, 10.0, vocab, min_prompt=PROMPT,
                               max_prompt=PROMPT, max_new_tokens=GEN,
                               seed=seed)


REPEATS = 5  # timed repeats per arm; best wall wins (shields CPU noise)


def prepare_arm(name: str, spec: SpeculationConfig | None,
                params: dict) -> Engine:
    """Build + warm one arm's engine (compiles prefill and the arm's
    StepFn keys outside every timed window)."""
    cfg = _cfg(spec)
    eng = Engine.build(cfg, params=params)
    warm = eng.run_trace(_reqs(cfg.model.vocab_size, SEED + 1),
                         max_steps=4000)
    assert warm["finished"] == warm["total"], (name, warm)
    return eng


def time_arm(name: str, eng: Engine) -> tuple:
    """One timed burst on a warmed engine -> (wall_s, summary, requests)."""
    # drop prior requests so each timed trace drains on its own count
    # (and so stats().speculation sums the last window only)
    eng.scheduler.finished.clear()
    reqs = _reqs(eng.cfg.model.vocab_size, SEED)
    t0 = time.time()
    out = eng.run_trace(reqs, max_steps=4000)
    wall = time.time() - t0
    assert out["finished"] == out["total"], (name, out)
    return wall, out, reqs


def summarize_arm(eng: Engine, best: tuple) -> dict:
    wall, out, reqs = best
    st = eng.stats()
    eng.scheduler.backend.pool.check_invariants()
    pct = latency_percentiles(reqs)
    return {
        "tokens": {r.req_id: tuple(r.generated) for r in reqs},
        "wall_s": wall, "steps": out["steps"],
        "generated_tokens": sum(r.n_generated for r in reqs),
        "tokens_per_s": sum(r.n_generated for r in reqs) / wall,
        "acceptance": st.speculation.acceptance,
        "proposed": st.speculation.proposed,
        "p50_itl_s": pct.get("p50_itl_s"), "p99_itl_s": pct.get("p99_itl_s"),
        "p50_ttft_s": pct.get("p50_ttft_s"),
    }


def main():
    n_layers = _cfg().model.n_layers
    draft = max(1, n_layers // 2)
    params = early_exit_params(_cfg(), draft)
    specs = {
        "single_token": None,
        # the gate arm: cheap early-exit draft at structural acceptance 1.0
        "speculative": SpeculationConfig(enabled=True, max_k=MAX_K,
                                         draft_layers=draft),
        # draft == target: isolates the tick-amortization share of the win
        "full_depth_draft": SpeculationConfig(enabled=True, max_k=MAX_K),
    }
    engines = {name: prepare_arm(name, spec, params)
               for name, spec in specs.items()}
    # interleave the timed repeats round-robin across arms so slow drift
    # of the shared CPU hits every arm equally instead of biasing one
    best: dict = {}
    for _ in range(REPEATS):
        for name, eng in engines.items():
            run = time_arm(name, eng)
            if name not in best or run[0] < best[name][0]:
                best[name] = run
    arms = {name: summarize_arm(eng, best[name])
            for name, eng in engines.items()}
    base = arms["single_token"]

    metrics = {"conditions": {
        "smoke": SMOKE, "arch": ARCH, "rows": ROWS, "n": N_REQUESTS,
        "prompt": PROMPT, "gen": GEN, "max_k": MAX_K,
        "n_layers": n_layers, "draft_layers": draft, "seed": SEED,
        "gate_speedup": GATE_SPEEDUP, "gate_acceptance": GATE_ACCEPTANCE,
    }}
    for name, r in arms.items():
        acc = "n/a" if r["acceptance"] is None else f"{r['acceptance']:.3f}"
        itl = "n/a" if r["p50_itl_s"] is None else f"{r['p50_itl_s']:.4f}"
        print(f"fig13/{ARCH}/{name},{r['wall_s'] * 1e6:.0f},"
              f"tokens_per_s={r['tokens_per_s']:.2f};steps={r['steps']};"
              f"acceptance={acc};proposed={r['proposed'] or 0};"
              f"p50_itl_s={itl}")
        # speculation must never change the output tokens
        assert r["tokens"] == base["tokens"], (name, "token mismatch")
        metrics[name] = {k: v for k, v in r.items() if k != "tokens"}

    spec_arm = arms["speculative"]
    speedup = spec_arm["tokens_per_s"] / base["tokens_per_s"]
    metrics["speedup"] = speedup
    metrics["speedup_full_depth"] = (arms["full_depth_draft"]["tokens_per_s"]
                                     / base["tokens_per_s"])
    print(f"fig13/speedup,0,spec_over_single={speedup:.3f};"
          f"full_depth={metrics['speedup_full_depth']:.3f};"
          f"acceptance={spec_arm['acceptance']:.3f}")
    assert spec_arm["acceptance"] >= GATE_ACCEPTANCE, (
        f"gated arm acceptance {spec_arm['acceptance']} < {GATE_ACCEPTANCE}")
    assert speedup >= GATE_SPEEDUP, (
        f"speculative speedup {speedup:.3f} < gate {GATE_SPEEDUP}")
    return metrics


if __name__ == "__main__":
    main()
