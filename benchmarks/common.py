"""Shared benchmark machinery.

``realized_lengths`` runs the *real* Ada-SnapKV / policy selection over
synthetic importance scores with controllable per-head skew, producing the
(L, H, B) retained-length tensors that drive the utilization / throughput
simulations (paper §3.1: the observable FairKV plans against).

``decode_time_model`` provides the per-shard latency model: the measured
bilinear fit from fig1 when available, else the v5e analytic roofline
(attention-decode HBM time + a uniform per-shard overhead for the dense
part).  Only relative shard times matter for E (Eq. 5); the uniform
overhead sets how much imbalance is visible end-to-end, and is reported
with every result.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (
    CompressionConfig,
    HeadPlacement,
    PlannerConfig,
    build_plan,
    profile_from_lengths,
    select_policy,
)
from repro.core.efficiency import owned_mask


def synthetic_scores(B: int, H: int, T: int, head_skew: float = 1.0,
                     head_seed: int = 0, data_seed: int = 0,
                     dataset_jitter: float = 0.35) -> jnp.ndarray:
    """(B, H, T) importance scores.

    The per-head location μ_h ~ N(0, skew²) is a *model* property (fixed by
    ``head_seed``); ``data_seed`` draws the per-dataset sample noise plus a
    moderate dataset-level shift of head importance (``dataset_jitter`` of
    the base skew) — the separation the paper's Table 1 relies on.
    """
    mu = np.random.default_rng(head_seed).normal(0.0, head_skew, size=H)
    rng = np.random.default_rng(data_seed)
    mu = mu + rng.normal(0.0, dataset_jitter * head_skew, size=H)
    raw = rng.lognormal(mean=mu[None, :, None], sigma=1.0, size=(B, H, T))
    return jnp.asarray(raw, jnp.float32)


def realized_lengths(n_layers: int, n_heads: int, budget: int, batch: int,
                     T: int = 8192, head_skew: float = 1.0,
                     policy: str = "ada_snapkv", head_seed: int = 0,
                     data_seed: int = 0, alpha_max: float = 4.0) -> np.ndarray:
    """(L, H, B) retained lengths from the actual policy selection."""
    ccfg = CompressionConfig(policy=policy, budget=budget,
                             alpha_max=alpha_max, obs_window=32, sink=4,
                             decode_margin=0)
    out = np.zeros((n_layers, n_heads, batch), dtype=np.int64)
    for li in range(n_layers):
        scores = synthetic_scores(batch, n_heads, T, head_skew,
                                  head_seed=head_seed * 1000 + li,
                                  data_seed=(data_seed * 7919 + li) * 104729)
        _, keep = select_policy(policy, scores, ccfg, li, n_layers)
        out[li] = np.asarray(keep).T
    return out


@dataclass
class DecodeTimeModel:
    """t_shard = overhead + Σ_owned lengths  (units: tokens-equivalent)."""

    overhead_tokens: float  # uniform per-shard work in retained-token units

    def shard_times(self, plan: HeadPlacement, lengths: np.ndarray) -> np.ndarray:
        L, H, B = lengths.shape
        S = plan.slots_per_shard
        t = np.full(plan.n_shards, self.overhead_tokens, dtype=np.float64)
        for j in range(plan.n_shards):
            tot = 0.0
            for li, lp in enumerate(plan.layers):
                for s in range(S):
                    slot = j * S + s
                    h = int(lp.slot_head[slot])
                    if h < 0:
                        continue
                    msk = owned_mask(int(lp.replica_idx[slot]),
                                     int(lp.replica_count[slot]), B)
                    tot += float(lengths[li, h, msk].sum())
            t[j] += tot
        return t

    def utilization(self, plan, lengths) -> float:
        t = self.shard_times(plan, lengths)
        return float(t.mean() / t.max())

    def throughput(self, plan, lengths) -> float:
        t = self.shard_times(plan, lengths)
        return float(lengths.shape[-1] / t.max())


def v5e_overhead_tokens(d_model: int, d_ff: int, n_layers: int, batch: int,
                        n_shards: int, head_dim: int,
                        params_bytes_per_shard: float) -> float:
    """Uniform per-shard decode work, expressed in retained-token units.

    One retained token costs 2·Dh·2 bytes of KV read per row.  The uniform
    part is dominated by the weight read (params_bytes / shard); converting:
    overhead_tokens = weight_bytes / (kv bytes per token-row).
    """
    kv_bytes_per_token = 2 * head_dim * 2.0
    return params_bytes_per_shard / kv_bytes_per_token / max(batch, 1)


def make_plans(lengths: np.ndarray, n_shards: int, ch: int = 4,
               slots: Optional[int] = None) -> Dict[str, HeadPlacement]:
    """Paper-semantics plans: SHA/NoDP place one copy per head; DP may add
    up to ``ch`` copies into the spare slots (a GPU hosting an extra head).
    The +1 slot is layout headroom — an empty slot is free at runtime."""
    prof = profile_from_lengths(lengths)
    H = prof.shape[1]
    slots = slots or (max(1, -(-H // n_shards)) + 1)
    common = dict(slots_per_shard=slots, fill_empty_slots=False)
    return {
        "sha": build_plan(prof, n_shards, PlannerConfig(
            mode="sha", **common)),
        "fairkv_nodp": build_plan(prof, n_shards, PlannerConfig(
            mode="fairkv_nodp", **common)),
        "fairkv_dp": build_plan(prof, n_shards, PlannerConfig(
            mode="fairkv_dp", extra_copies=ch, **common)),
    }


def timed(fn, *args, warmup: int = 2, iters: int = 5) -> Tuple[float, object]:
    """Median wall time (µs) of jitted fn."""
    out = None
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts)), out


# paper-like model dims for the simulation benchmarks
SIM_MODELS = {
    "llama70b-like(qwen1.5-110b)": dict(n_layers=80, n_heads=8, d_model=8192,
                                        d_ff=49152, head_dim=128),
    "llama8b-like(minitron-8b)": dict(n_layers=32, n_heads=8, d_model=4096,
                                      d_ff=16384, head_dim=128),
    "mistral24b-like(llava-34b)": dict(n_layers=60, n_heads=8, d_model=7168,
                                       d_ff=20480, head_dim=128),
}
