"""Table 1: cosine similarity of retained-KV profiles across datasets.

The paper's claim: per-head budget allocation is dataset-invariant
(cosine ≥ 0.94 for 70B, ≥ 0.87 for 8B), so a statically planned FairKV
layout transfers.  We reproduce by running the real Ada-SnapKV selection on
synthetic "datasets" (distinct score distributions per seed, same per-head
skew pattern — the head identity is a *model* property, which is exactly the
paper's point) and report pairwise profile cosines.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import realized_lengths
from repro.core import cosine_similarity, profile_from_lengths


def run(budgets=(128, 256, 512, 1024), n_datasets: int = 8,
        n_layers: int = 8, n_heads: int = 8) -> list:
    rows = []
    rng = np.random.default_rng(0)
    # the model's head-skew pattern is fixed; datasets perturb the scores
    for budget in budgets:
        profiles = []
        for ds in range(n_datasets):
            # head pattern fixed (model property), data noise varies per set
            lengths = realized_lengths(n_layers, n_heads, budget, batch=8,
                                       T=4096, head_skew=1.0,
                                       head_seed=0, data_seed=ds + 1)
            profiles.append(profile_from_lengths(lengths))
        sims = []
        for i in range(n_datasets):
            for j in range(i + 1, n_datasets):
                sims.append(cosine_similarity(profiles[i], profiles[j]))
        sims = np.array(sims)
        rows.append({
            "name": f"table1/ada_snapkv_budget{budget}",
            "avg": float(sims.mean()), "max": float(sims.max()),
            "min": float(sims.min()), "std": float(sims.std()),
        })
    return rows


def main():
    for r in run():
        print(f"{r['name']},0,avg={r['avg']:.3f};max={r['max']:.3f};"
              f"min={r['min']:.3f};std={r['std']:.3f}")


if __name__ == "__main__":
    main()
