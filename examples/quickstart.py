"""Quickstart: FairKV end to end on a reduced model (CPU, ~1 min).

Everything goes through the `repro.api` facade:

1. build an `Engine` and measure the per-head workload profile (the
   compression statistic) with a profiling prefill,
2. rebuild the engine under three planners (SHA / best-effort /
   fair-copying) against the measured profile,
3. `Engine.generate` a batch under each plan,
4. show that logits are identical (the plan is a layout, not math) while
   the simulated shard balance improves.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.api import CompressionConfig, Engine, EngineConfig, PlannerConfig
from repro.configs.base import InputShape
from repro.training.data import SyntheticLM

ARCH = "minitron-8b"
SHARDS = 8
BUDGET = 24
T, B, GEN = 96, 2, 8


def main():
    base_cfg = EngineConfig.smoke(
        ARCH, n_shards=SHARDS, max_seq_len=T + GEN + 8,
        compression=CompressionConfig(policy="ada_snapkv", budget=BUDGET,
                                      alpha_max=2.0, obs_window=8, sink=2,
                                      decode_margin=8),
        planner=PlannerConfig(mode="sha", batch_cap=B))
    data = SyntheticLM(base_cfg.model, InputShape("qs", T, B, "prefill"))
    batch = data.get_batch(0)

    # --- profiling pass (paper §4.1): run the compression policy on a sample
    # batch and measure the per-head retained lengths; the planner consumes
    # the measured profile, exactly like the paper's offline statistics
    probe = Engine.build(base_cfg)
    profile = probe.measure_profile(data.get_batch(123))
    print(f"measured profile per-head mean budgets: "
          f"{profile.mean(0).round(1).tolist()}\n")

    results = {}
    for mode, ch in [("sha", 0), ("fairkv_nodp", 0), ("fairkv_dp", 4)]:
        cfg = base_cfg.replace(planner=PlannerConfig(
            mode=mode, extra_copies=ch, batch_cap=B))
        # shared params: the plan is a layout over one set of weights
        eng = Engine.build(cfg, params=probe.params, profile=profile)
        res = eng.generate(batch, GEN)
        results[mode] = {
            "logits": jnp.asarray(res.logits),
            "E": res.efficiency,
            "makespan": res.makespan,
            "tokens": res.tokens[:, -1],
        }
        print(f"{mode:13s} E={res.efficiency:.3f} "
              f"makespan={res.makespan:8.1f} "
              f"last tokens={res.tokens[:, -1].tolist()}")

    d = float(jnp.abs(results["sha"]["logits"]
                      - results["fairkv_dp"]["logits"]).max())
    print(f"\nplan-invariance: max |logits_SHA - logits_FairKV| = {d:.2e}")
    gain = results["sha"]["makespan"] / results["fairkv_dp"]["makespan"]
    print(f"balance gain (SHA makespan / FairKV-DP makespan) = {gain:.2f}x")
    assert d < 1e-3


if __name__ == "__main__":
    main()
