"""Quickstart: FairKV end to end on a reduced model (CPU, ~1 min).

Everything goes through the `repro.api` facade:

1. build an `Engine` and measure the per-head workload profile (the
   compression statistic) with a profiling prefill,
2. rebuild the engine under three planners (SHA / best-effort /
   fair-copying) against the measured profile,
3. `Engine.generate` a batch under each plan,
4. show that logits are identical (the plan is a layout, not math) while
   the simulated shard balance improves,
5. print the realized cache-memory footprint of the selected cache
   backend — with ``--cache-backend paged`` the block pool only pins
   memory proportional to the realized per-head retained lengths, so the
   footprint line shows the win over the dense slot cache in one glance.

Run:  PYTHONPATH=src python examples/quickstart.py [--cache-backend paged]
"""
import argparse

import jax.numpy as jnp

from repro.api import (
    CompressionConfig,
    Engine,
    EngineConfig,
    PagingConfig,
    PlannerConfig,
    list_cache_backends,
)
from repro.configs.base import InputShape
from repro.training.data import SyntheticLM

ARCH = "minitron-8b"
SHARDS = 8
BUDGET = 24
T, B, GEN = 96, 2, 8


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--cache-backend", default="slot",
                    help=f"cache backend; registered: {list_cache_backends()}")
    ap.add_argument("--block-size", type=int, default=8,
                    help="paged backend: tokens per KV block")
    args = ap.parse_args(argv)

    base_cfg = EngineConfig.smoke(
        ARCH, n_shards=SHARDS, max_seq_len=T + GEN + 8,
        compression=CompressionConfig(policy="ada_snapkv", budget=BUDGET,
                                      alpha_max=2.0, obs_window=8, sink=2,
                                      decode_margin=8),
        planner=PlannerConfig(mode="sha", batch_cap=B),
        cache_backend=args.cache_backend,
        paging=PagingConfig(block_size=args.block_size))
    data = SyntheticLM(base_cfg.model, InputShape("qs", T, B, "prefill"))
    batch = data.get_batch(0)

    # --- profiling pass (paper §4.1): run the compression policy on a sample
    # batch and measure the per-head retained lengths; the planner consumes
    # the measured profile, exactly like the paper's offline statistics
    probe = Engine.build(base_cfg)
    profile = probe.measure_profile(data.get_batch(123))
    print(f"measured profile per-head mean budgets: "
          f"{profile.mean(0).round(1).tolist()}\n")

    results = {}
    mem = None
    for mode, ch in [("sha", 0), ("fairkv_nodp", 0), ("fairkv_dp", 4)]:
        cfg = base_cfg.replace(planner=PlannerConfig(
            mode=mode, extra_copies=ch, batch_cap=B))
        # shared params: the plan is a layout over one set of weights
        eng = Engine.build(cfg, params=probe.params, profile=profile)
        res = eng.generate(batch, GEN)
        results[mode] = {
            "logits": jnp.asarray(res.logits),
            "E": res.efficiency,
            "makespan": res.makespan,
            "tokens": res.tokens[:, -1],
        }
        mem = eng.memory_stats()
        print(f"{mode:13s} E={res.efficiency:.3f} "
              f"makespan={res.makespan:8.1f} "
              f"last tokens={res.tokens[:, -1].tolist()}")

    d = float(jnp.abs(results["sha"]["logits"]
                      - results["fairkv_dp"]["logits"]).max())
    print(f"\nplan-invariance: max |logits_SHA - logits_FairKV| = {d:.2e}")
    gain = results["sha"]["makespan"] / results["fairkv_dp"]["makespan"]
    print(f"balance gain (SHA makespan / FairKV-DP makespan) = {gain:.2f}x")
    assert d < 1e-3

    # --- realized memory footprint of the selected backend ------------------
    if mem.get("backend") == "paged":
        slot_eq = mem["slot_equivalent_bytes"]
        print(f"\ncache footprint [paged]: {mem['cache_bytes']} B in "
              f"{mem['blocks_in_use']} blocks of {mem['block_size']} tokens "
              f"vs slot-cache {slot_eq} B "
              f"({slot_eq / max(1, mem['cache_bytes']):.2f}x saved)")
    else:
        print(f"\ncache footprint [slot]: {mem['cache_bytes']} B reserved, "
              f"{mem['live_tokens']}/{mem['capacity_tokens']} tokens live "
              f"({100 * mem['utilization']:.0f}% utilized) — rerun with "
              f"--cache-backend paged to pay only for what is retained")


if __name__ == "__main__":
    main()
