"""Quickstart: FairKV end to end on a reduced model (CPU, ~1 min).

1. build a per-head workload profile (the compression statistic),
2. plan head placement three ways (SHA / best-effort / fair-copying),
3. serve a batch through prefill+compression+decode under each plan,
4. show that logits are identical (the plan is a layout, not math) while
   the simulated shard balance improves.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.slot_cache import PlanArrays
from repro.compression.base import CompressionConfig
from repro.configs import get_smoke_config
from repro.core import PlannerConfig, build_plan, profile_from_lengths, synthetic_profile
from repro.models import init_params
from repro.serving import decode_step, prefill, slotify_params
from repro.training.data import SyntheticLM
from repro.configs.base import InputShape

ARCH = "minitron-8b"
SHARDS = 8
BUDGET = 24
T, B, GEN = 96, 2, 8


def main():
    cfg = get_smoke_config(ARCH)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32,
                         max_seq_len=T + GEN + 8)
    data = SyntheticLM(cfg, InputShape("qs", T, B, "prefill"))
    batch = data.get_batch(0)
    ccfg = CompressionConfig(policy="ada_snapkv", budget=BUDGET,
                             alpha_max=2.0, obs_window=8, sink=2,
                             decode_margin=8)

    # --- profiling pass (paper §4.1): run the compression policy on a sample
    # batch and measure the per-head retained lengths; the planner consumes
    # the measured profile, exactly like the paper's offline statistics
    trivial = build_plan(np.ones((cfg.n_layers, cfg.n_kv_heads)), SHARDS,
                         PlannerConfig(mode="sha"))
    sp0 = slotify_params(params, trivial, cfg)
    _, _, lens0 = prefill(sp0, data.get_batch(123), cfg,
                          PlanArrays.from_plan(trivial), ccfg)
    profile = profile_from_lengths(np.asarray(lens0, np.float64))
    print(f"measured profile per-head mean budgets: "
          f"{profile.mean(0).round(1).tolist()}\n")

    results = {}
    for mode, ch in [("sha", 0), ("fairkv_nodp", 0), ("fairkv_dp", 4)]:
        plan = build_plan(profile, SHARDS,
                          PlannerConfig(mode=mode, extra_copies=ch,
                                        batch_cap=B))
        pa = PlanArrays.from_plan(plan)
        sp = slotify_params(params, plan, cfg)
        state, logits, lens = prefill(sp, batch, cfg, pa, ccfg)
        outs = [logits]
        for _ in range(GEN):
            state, logits = decode_step(sp, state, cfg, pa, ccfg)
            outs.append(logits)
        realized = profile_from_lengths(np.asarray(lens, np.float64))
        results[mode] = {
            "logits": jnp.stack(outs, 1),
            "E": plan.efficiency(realized),
            "makespan": plan.makespan(realized),
            "tokens": np.asarray(state.last_tokens),
        }
        print(f"{mode:13s} E={results[mode]['E']:.3f} "
              f"makespan={results[mode]['makespan']:8.1f} "
              f"last tokens={results[mode]['tokens'].tolist()}")

    d = float(jnp.abs(results["sha"]["logits"]
                      - results["fairkv_dp"]["logits"]).max())
    print(f"\nplan-invariance: max |logits_SHA - logits_FairKV| = {d:.2e}")
    gain = results["sha"]["makespan"] / results["fairkv_dp"]["makespan"]
    print(f"balance gain (SHA makespan / FairKV-DP makespan) = {gain:.2f}x")
    assert d < 1e-3


if __name__ == "__main__":
    main()
