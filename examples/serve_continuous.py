"""Example: continuous batching with slot-aware admission + online replanning.

A short Poisson trace of requests flows through the scheduler on a smoke
config: requests queue while the batch is full, get admitted into freed rows
mid-stream, and — because Ada-SnapKV's per-head budgets are imbalanced — the
realized per-shard KV load drifts.  The replan trigger is set aggressively so
the trace demonstrates an online replan: the head placement is rebuilt from
the *realized* profile, the live cache is migrated into the new slot layout,
and decoding continues without interruption.

Run:  PYTHONPATH=src JAX_PLATFORMS=cpu python examples/serve_continuous.py
"""
import jax
import jax.numpy as jnp

from repro.compression.base import CompressionConfig
from repro.configs import get_smoke_config
from repro.core import PlannerConfig, build_plan, synthetic_profile
from repro.models import init_params
from repro.serving import (
    Scheduler,
    SchedulerConfig,
    latency_percentiles,
    synthesize_requests,
)

ARCH = "minitron-8b"
ROWS = 4
SHARDS = 4
GEN = 10


def main():
    cfg = get_smoke_config(ARCH)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32,
                         max_seq_len=64)
    ccfg = CompressionConfig(policy="ada_snapkv", budget=16, alpha_max=2.0,
                             obs_window=8, sink=2, decode_margin=8)
    # plan against a synthetic profile; the replan will use the realized one
    prof = synthetic_profile(cfg.n_layers, cfg.n_kv_heads, budget=16,
                             skew=1.0, seed=1)
    pcfg = PlannerConfig(mode="fairkv_dp", extra_copies=4, batch_cap=ROWS)
    plan = build_plan(prof, SHARDS, pcfg)
    scfg = SchedulerConfig(max_rows=ROWS, replan_window=4,
                           replan_threshold=1.05, replan_cooldown=10)
    sched = Scheduler(cfg, params, plan, ccfg, scfg, planner_cfg=pcfg)

    reqs = synthesize_requests(8, rate=0.4, vocab_size=cfg.vocab_size,
                               min_prompt=12, max_prompt=28,
                               max_new_tokens=GEN, seed=3)
    print(f"{len(reqs)} requests, arrivals at steps "
          f"{[r.arrival_step for r in reqs]}")
    out = sched.run(reqs, max_steps=500)

    print("\nper-request latency:")
    for r in sched.finished:
        print(f"  req {r.req_id}: prompt {r.prompt_len:3d} | queued "
              f"{r.queueing_steps():2d} steps | total {r.latency_steps():3d} "
              f"steps | {r.n_generated} tokens")
    pct = latency_percentiles(sched.finished)
    print(f"\np50 {pct['p50_steps']:.0f} / p99 {pct['p99_steps']:.0f} steps | "
          f"{out['generated_tokens']} tokens | "
          f"mid-stream admissions {out['mid_stream_admissions']}")
    if out["replan_log"]:
        for ev in out["replan_log"]:
            tag = "accepted" if ev["accepted"] else "rejected"
            print(f"replan @ step {ev['step']} ({tag}): realized imbalance "
                  f"{ev['imbalance_before']:.3f} -> "
                  f"{ev['imbalance_after']:.3f}")
    else:
        print("no replan fired (trace too balanced) — rerun with a different "
              "seed or lower SchedulerConfig.replan_threshold")
    assert out["finished"] == out["total"]


if __name__ == "__main__":
    main()
