"""Example: streaming continuous batching through the `repro.api` facade.

A short Poisson trace of requests flows through `Engine.stream` on a smoke
config: requests queue while the batch is full, get admitted into freed rows
mid-stream, and — because Ada-SnapKV's per-head budgets are imbalanced — the
realized per-shard KV load drifts.  The replan trigger is set aggressively so
the trace demonstrates an online replan: the head placement is rebuilt from
the *realized* profile, the live cache is migrated into the new slot layout,
and decoding continues without interruption.  `Engine.stream` yields one
`StreamEvent` per generated token, so the example also shows request-level
token streaming.

``--executor mesh`` runs the same trace with the StepFns under
``shard_map`` on a (data, model) host mesh (DESIGN.md §10) — fake the
devices first with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Run:  PYTHONPATH=src JAX_PLATFORMS=cpu python examples/serve_continuous.py \
          [--cache-backend paged] [--executor mesh [--data 2]]
"""
import argparse

from repro.api import (
    CompressionConfig,
    Engine,
    EngineConfig,
    PagingConfig,
    PlannerConfig,
    SchedulerConfig,
    latency_percentiles,
    list_cache_backends,
    list_executors,
    synthesize_requests,
)

ARCH = "minitron-8b"
ROWS = 4
SHARDS = 4
GEN = 10


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--cache-backend", default="slot",
                    help=f"cache backend; registered: {list_cache_backends()}")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--executor", default="local",
                    help=f"execution strategy; registered: {list_executors()}")
    ap.add_argument("--data", type=int, default=1,
                    help="mesh executor: data-axis width")
    args = ap.parse_args(argv)

    mesh = None
    if args.executor == "mesh":
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model=SHARDS, data=args.data)

    cfg = EngineConfig.smoke(
        ARCH, n_shards=SHARDS, max_seq_len=64,
        compression=CompressionConfig(policy="ada_snapkv", budget=16,
                                      alpha_max=2.0, obs_window=8, sink=2,
                                      decode_margin=8),
        # plan against a synthetic profile; the replan will use the realized
        # one (EngineConfig.profile_seed/skew control the synthetic draw)
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=4,
                              batch_cap=ROWS),
        scheduler=SchedulerConfig(max_rows=ROWS, replan_window=4,
                                  replan_threshold=1.05, replan_cooldown=10),
        cache_backend=args.cache_backend,
        paging=PagingConfig(block_size=args.block_size),
        executor=args.executor)
    eng = Engine.build(cfg, mesh=mesh)

    reqs = synthesize_requests(8, rate=0.4, vocab_size=cfg.model.vocab_size,
                               min_prompt=12, max_prompt=28,
                               max_new_tokens=GEN, seed=3)
    print(f"{len(reqs)} requests, arrivals at steps "
          f"{[r.arrival_step for r in reqs]}")
    n_tokens = 0
    for ev in eng.stream(reqs, max_steps=500):
        n_tokens += 1
        if ev.finished:
            print(f"  [stream] req {ev.req_id} finished at step {ev.step} "
                  f"({ev.index + 1} tokens)")
    assert len(eng.finished_requests) == len(reqs), (
        f"only {len(eng.finished_requests)}/{len(reqs)} requests finished "
        f"within max_steps")

    print("\nper-request latency:")
    for r in eng.finished_requests:
        print(f"  req {r.req_id}: prompt {r.prompt_len:3d} | queued "
              f"{r.queueing_steps():2d} steps | total {r.latency_steps():3d} "
              f"steps | {r.n_generated} tokens")
    pct = latency_percentiles(eng.finished_requests)
    # decode starts the same tick the first request is admitted, so
    # mid-stream == admitted after the earliest admission tick (matches the
    # scheduler's run() accounting)
    first_admit = min(r.admit_step for r in eng.finished_requests)
    mid = sum(1 for r in eng.finished_requests
              if r.admit_step > first_admit)
    print(f"\np50 {pct['p50_steps']:.0f} / p99 {pct['p99_steps']:.0f} steps | "
          f"{n_tokens} tokens streamed | mid-stream admissions {mid}")
    mem = eng.memory_stats()
    if mem.get("backend") == "paged":
        print(f"paged cache: {mem['blocks_in_use']} blocks in use "
              f"(pool {mem['pool_bytes']} B) vs slot-equivalent "
              f"{mem['slot_equivalent_bytes']} B")
    if eng.replan_log:
        for ev in eng.replan_log:
            tag = "accepted" if ev["accepted"] else "rejected"
            print(f"replan @ step {ev['step']} ({tag}): realized imbalance "
                  f"{ev['imbalance_before']:.3f} -> "
                  f"{ev['imbalance_after']:.3f}")
    else:
        print("no replan fired (trace too balanced) — rerun with a different "
              "seed or lower SchedulerConfig.replan_threshold")


if __name__ == "__main__":
    main()
