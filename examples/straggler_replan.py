"""Example: straggler mitigation through FairKV re-planning.

A shard running at 0.5× speed is detected from step-time telemetry; the
planner rebuilds the head placement with per-shard speed factors (the
heterogeneous generalization of Eq. 4), shrinking the straggler's share of
the retained-KV load and recovering most of the lost throughput.

This is a planner-level simulation (no model weights), so it uses the
planning building blocks re-exported by `repro.api`; the same path runs
live on a weight-carrying engine via ``Engine.replan(shard_speeds=...)``.

Run:  PYTHONPATH=src python examples/straggler_replan.py
"""
import numpy as np

from repro.api import (
    PlannerConfig,
    build_plan,
    replan_for_stragglers,
    synthetic_profile,
)
from repro.training import StragglerDetector

SHARDS = 8


def simulated_step_times(plan, profile, speeds):
    load = plan.per_shard_load(profile)
    return load / speeds


def main():
    profile = synthetic_profile(32, 8, budget=1024, skew=1.0, seed=3)
    plan = build_plan(profile, SHARDS,
                      PlannerConfig(mode="fairkv_dp", extra_copies=4))
    speeds = np.ones(SHARDS)
    speeds[5] = 0.5  # shard 5 degrades (thermal throttle, flaky HBM, ...)

    det = StragglerDetector(n_shards=SHARDS, min_samples=3)
    factors = None
    for step in range(10):
        t = simulated_step_times(plan, profile, speeds)
        factors = det.observe(t) if factors is None else factors
    assert factors is not None, "straggler not detected"
    print(f"detected speed factors: {np.round(factors, 2).tolist()}")

    before = simulated_step_times(plan, profile, speeds).max()
    new_plan = replan_for_stragglers(profile, plan, factors)
    after = simulated_step_times(new_plan, profile, speeds).max()
    healthy = simulated_step_times(plan, profile, np.ones(SHARDS)).max()
    print(f"step time: healthy {healthy:8.0f} | degraded {before:8.0f} | "
          f"replanned {after:8.0f}")
    print(f"recovered {100 * (before - after) / (before - healthy):.0f}% of "
          f"the straggler-induced slowdown")


if __name__ == "__main__":
    main()
