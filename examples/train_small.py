"""Example: train a ~small LM for a few hundred steps with checkpoints.

Exercises the full training substrate (AdamW + remat + deterministic data +
async checkpointing + bit-exact resume).  ~2-4 min on this CPU.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse
import os
import shutil
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import InputShape
from repro.models import init_params, param_count
from repro.training import (
    OptimizerConfig,
    SupervisorConfig,
    SyntheticLM,
    TrainingSupervisor,
    init_optimizer,
    make_train_step,
)

CKPT = "/tmp/repro_example_train"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="granite-3-2b")
    args = ap.parse_args()
    shutil.rmtree(CKPT, ignore_errors=True)

    cfg = get_smoke_config(args.arch)
    data = SyntheticLM(cfg, InputShape("ex", 64, 4, "train"))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    print(f"{cfg.name}: {param_count(params):,} params")
    opt = init_optimizer(params)
    ocfg = OptimizerConfig(lr=2e-3, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
    sup = TrainingSupervisor(SupervisorConfig(checkpoint_dir=CKPT,
                                              checkpoint_every=100))

    def one(st, batch):
        p, o, m = step_fn(st["params"], st["opt"], batch)
        return {"params": p, "opt": o}, m

    state = {"params": params, "opt": opt}
    t0 = time.time()
    _, state, metrics = sup.run(state, one, data.get_batch, args.steps)
    print(f"final loss {float(metrics['loss']):.4f} in "
          f"{time.time() - t0:.1f}s; checkpoints: "
          f"{sorted(os.listdir(CKPT))}")

    # simulate a preemption + resume: restore the last checkpoint and verify
    # the replayed step stream produces a finite, continuing loss
    step0, restored = sup.restore_or_init(state)
    print(f"resume check: restored at step {step0}")
    _, m = one(restored, data.get_batch(step0))
    print(f"post-restore step loss {float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
